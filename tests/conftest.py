import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture()
def store():
    """Fresh in-memory provenance store + default runner per test."""
    from repro.engine.runner import set_default_runner
    from repro.provenance.store import configure_store

    st = configure_store(":memory:")
    set_default_runner(None)
    yield st
    set_default_runner(None)


@pytest.fixture()
def runner(store):
    from repro.engine.runner import Runner, set_default_runner

    r = Runner(store=store)
    set_default_runner(r)
    yield r
