import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_caching_state(monkeypatch):
    """Caching is policy-gated global state; isolate it per test."""
    from repro.caching.config import ENV_VAR, reset_policy

    monkeypatch.delenv(ENV_VAR, raising=False)
    reset_policy()
    yield
    reset_policy()


@pytest.fixture()
def store():
    """Fresh in-memory provenance store + default runner per test."""
    from repro.engine.runner import set_default_runner
    from repro.provenance.store import configure_store

    st = configure_store(":memory:")
    set_default_runner(None)
    yield st
    set_default_runner(None)


@pytest.fixture()
def runner(store):
    from repro.engine.runner import Runner, set_default_runner

    r = Runner(store=store)
    set_default_runner(r)
    yield r
