"""CLI smoke tests (the verdi role)."""

import os

import pytest

from repro import cli
from repro.core import Int, calcfunction
from repro.engine.runner import Runner, set_default_runner
from repro.provenance.store import configure_store


@pytest.fixture()
def profile(tmp_path):
    db = str(tmp_path / "profile.db")
    store = configure_store(db)
    set_default_runner(Runner(store=store))

    @calcfunction
    def add(a, b):
        return a + b

    add(Int(1), Int(2))
    store.close()
    set_default_runner(None)
    return db


def test_process_list(profile, capsys):
    cli.main(["-p", profile, "process", "list"])
    out = capsys.readouterr().out
    assert "add" in out and "finished" in out


def test_process_report_and_show(profile, capsys):
    cli.main(["-p", profile, "process", "list"])
    capsys.readouterr()
    cli.main(["-p", profile, "process", "report", "1"])
    out = capsys.readouterr().out
    assert "add<1>" in out
    cli.main(["-p", profile, "process", "show", "1"])
    out = capsys.readouterr().out
    assert "input_calc" in out and "create" in out


def test_graph_export(profile, tmp_path, capsys):
    out_file = str(tmp_path / "g.dot")
    cli.main(["-p", profile, "graph", "export", "1", "--out", out_file])
    content = open(out_file).read()
    assert content.startswith("digraph provenance")
    assert "n1" in content and "->" in content


def test_stats(profile, capsys):
    cli.main(["-p", profile, "stats"])
    out = capsys.readouterr().out
    assert "process.calcfunction" in out
    assert "unfinished processes: 0" in out


def test_process_inputs_spec_dump(profile, capsys):
    cli.main(["-p", profile, "process", "inputs",
              "repro.calcjobs:TPUTrainJob"])
    out = capsys.readouterr().out
    assert "TPUTrainJob" in out
    assert "config" in out and "Dict" in out and "required" in out
    assert "metadata/" in out and "non_db" in out
    assert "ERROR_NAN_LOSS" in out


def test_process_inputs_bare_name_and_bad_name(profile, capsys):
    cli.main(["-p", profile, "process", "inputs", "TPUTrainJob"])
    out = capsys.readouterr().out
    assert "repro.calcjobs" in out
    with pytest.raises(SystemExit, match="cannot resolve"):
        cli.main(["-p", profile, "process", "inputs", "NopeNotAClass"])


def test_cache_stats_reports_collisions(profile, capsys):
    cli.main(["-p", profile, "cache", "stats"])
    out = capsys.readouterr().out
    assert "collisions" in out
    assert "0 hash-collision occurrence(s)" in out
