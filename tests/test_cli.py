"""CLI smoke tests (the verdi role)."""

import os

import pytest

from repro import cli
from repro.core import Int, calcfunction
from repro.engine.runner import Runner, set_default_runner
from repro.provenance.store import configure_store


@pytest.fixture()
def profile(tmp_path):
    db = str(tmp_path / "profile.db")
    store = configure_store(db)
    set_default_runner(Runner(store=store))

    @calcfunction
    def add(a, b):
        return a + b

    add(Int(1), Int(2))
    store.close()
    set_default_runner(None)
    return db


def test_process_list(profile, capsys):
    cli.main(["-p", profile, "process", "list"])
    out = capsys.readouterr().out
    assert "add" in out and "finished" in out


def test_process_report_and_show(profile, capsys):
    cli.main(["-p", profile, "process", "list"])
    capsys.readouterr()
    cli.main(["-p", profile, "process", "report", "1"])
    out = capsys.readouterr().out
    assert "add<1>" in out
    cli.main(["-p", profile, "process", "show", "1"])
    out = capsys.readouterr().out
    assert "input_calc" in out and "create" in out


def test_graph_export(profile, tmp_path, capsys):
    out_file = str(tmp_path / "g.dot")
    cli.main(["-p", profile, "graph", "export", "1", "--out", out_file])
    content = open(out_file).read()
    assert content.startswith("digraph provenance")
    assert "n1" in content and "->" in content


def test_stats(profile, capsys):
    cli.main(["-p", profile, "stats"])
    out = capsys.readouterr().out
    assert "process.calcfunction" in out
    assert "unfinished processes: 0" in out
