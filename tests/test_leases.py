"""Lease semantics (grant/renew/expire/epoch-bump), store epoch fencing,
broker crash recovery, and ``repro store fsck`` detection + repair.

Broker-side tests drive :class:`BrokerServer` internals directly (no
sockets) the way test_chaos.py does; store-side tests run against the
in-memory fixture. The end-to-end zombie/broker-kill behaviour lives in
the chaos scenarios (tests/test_chaos.py)."""

import asyncio
import json
import sqlite3
import time

import pytest

from repro.engine.broker import _TASKS_SCHEMA, BrokerServer
from repro.provenance.store import NodeType, StaleEpochError


class _FakeWriter:
    def __init__(self):
        self.frames = []

    def is_closing(self):
        return False

    def write(self, data):
        self.frames.append(data)


def _server(tmp_path):
    return BrokerServer(str(tmp_path / "broker.db"))


# ---------------------------------------------------------------------------
# lease grant / renew / expire / epoch bump
# ---------------------------------------------------------------------------

def test_lease_grant_renew_and_handoff_bump(tmp_path):
    srv = _server(tmp_path)
    srv._names["c1"] = "wA"
    srv._names["c2"] = "wB"
    # first grant creates the lease at epoch 1
    assert srv._grant_lease(5, "c1") == 1
    # re-delivery to the SAME worker renews without bumping — a worker
    # that merely reconnected must not fence its own live coroutine
    assert srv._grant_lease(5, "c1") == 1
    assert srv.stats["leases_granted"] == 1
    # hand-off to a different worker arms the fence
    assert srv._grant_lease(5, "c2") == 2
    srv._commit_now()
    row = srv.conn().execute(
        "SELECT worker, epoch FROM leases WHERE pk=5").fetchone()
    assert row["worker"] == "wB" and row["epoch"] == 2


def test_drop_client_expires_lease_without_bump(tmp_path):
    srv = _server(tmp_path)
    srv._clients["c1"] = _FakeWriter()
    srv._names["c1"] = "wA"
    srv._grant_lease(9, "c1")

    srv._drop_client("c1")

    # expired: holder cleared, epoch NOT bumped (the bump happens at the
    # next grant to a different worker), durable row matches
    assert srv._leases[9] == [None, 1]
    assert srv.stats["leases_expired"] == 1
    row = srv.conn().execute(
        "SELECT worker, epoch FROM leases WHERE pk=9").fetchone()
    assert row["worker"] is None and row["epoch"] == 1


def test_reconnect_reowns_expired_lease_without_bump(tmp_path):
    srv = _server(tmp_path)
    srv._clients["c1"] = _FakeWriter()
    srv._names["c1"] = "wA"
    srv._grant_lease(9, "c1")
    srv._drop_client("c1")

    # the same worker NAME comes back under a fresh connection and
    # re-owns at the epoch it holds: restored, not refused, not bumped
    w = _FakeWriter()
    srv._clients["c2"] = w
    asyncio.run(srv._handle("c2", {"kind": "hello", "worker": "wA"}))
    asyncio.run(srv._handle("c2", {"kind": "own", "pks": [9],
                                   "epochs": {"9": 1}}))
    assert srv._leases[9] == ["wA", 1]
    assert srv._owners[9] == "c2"
    assert not any(b"own_refused" in f for f in w.frames)


def test_stale_own_claim_refused(tmp_path):
    srv = _server(tmp_path)
    w = _FakeWriter()
    srv._clients["c1"] = w
    srv._names["c1"] = "wA"
    srv._leases[7] = ["wB", 3]  # pk 7 was re-leased to wB at epoch 3

    asyncio.run(srv._handle("c1", {"kind": "own", "pks": [7],
                                   "epochs": {"7": 1}}))

    # the zombie's claim is refused: no ownership, counted, told why
    assert 7 not in srv._owners
    assert srv.stats["stale_claims"] == 1
    reply = json.loads(w.frames[-1].decode())
    assert reply["kind"] == "own_refused" and reply["pks"] == [7]


def test_zombie_ack_cannot_settle_requeued_task(tmp_path):
    srv = _server(tmp_path)
    srv.conn().execute(
        "INSERT INTO tasks (id, queue, payload, state, consumer,"
        " created_at) VALUES (1, 'q', '{}', 'inflight', 'c2', 0)")
    srv._commit_now()

    # c1 (the previous holder) acks a task that is now inflight to c2:
    # the consumer guard must leave the row untouched
    asyncio.run(srv._handle("c1", {"kind": "ack", "task_id": 1}))
    row = srv.conn().execute(
        "SELECT state, consumer FROM tasks WHERE id=1").fetchone()
    assert row["state"] == "inflight" and row["consumer"] == "c2"

    # the rightful holder settles it
    asyncio.run(srv._handle("c2", {"kind": "ack", "task_id": 1}))
    assert srv.conn().execute(
        "SELECT COUNT(*) FROM tasks").fetchone()[0] == 0


# ---------------------------------------------------------------------------
# broker crash recovery
# ---------------------------------------------------------------------------

def test_broker_restart_recovers_leases_and_requeues(tmp_path):
    db = str(tmp_path / "broker.db")
    srv1 = BrokerServer(db)
    srv1._names["c1"] = "wA"
    assert srv1._grant_lease(3, "c1") == 1
    srv1.conn().execute(
        "INSERT INTO tasks (queue, payload, state, consumer, created_at)"
        " VALUES ('process.queue', ?, 'inflight', 'c1', 0)",
        (json.dumps({"pk": 3}),))
    srv1._commit_now()
    srv1._conn.close()  # the old broker process is gone (kill -9)

    srv2 = BrokerServer(db)
    srv2._recover()
    # the lease survives verbatim: same holder name, same epoch — a
    # reconnecting wA is not fenced by the broker having died
    assert srv2._leases[3] == ["wA", 1]
    # the dead broker's inflight task is requeued (its consumer's
    # connection died with the old process)
    row = srv2.conn().execute(
        "SELECT state, consumer FROM tasks").fetchone()
    assert row["state"] == "ready" and row["consumer"] is None
    # renewal stamps were refreshed: reconnecting workers get a full
    # grace window before the reaper may expire anything
    renewed = srv2.conn().execute(
        "SELECT renewed_at FROM leases WHERE pk=3").fetchone()[0]
    assert renewed > time.time() - 5.0


# ---------------------------------------------------------------------------
# store epoch fencing
# ---------------------------------------------------------------------------

def test_fence_epoch_monotonic(store):
    pk = store.create_process_node(NodeType.CALC_FUNCTION, "P")
    store.fence_epoch(pk, None)   # broker-less runs: no-op
    store.fence_epoch(pk, 2)
    store.fence_epoch(pk, 2)      # same epoch: still the holder
    store.fence_epoch(pk, 5)      # monotonic advance
    with pytest.raises(StaleEpochError) as err:
        store.fence_epoch(pk, 3)
    assert err.value.pk == pk and err.value.epoch == 3
    with pytest.raises(KeyError):
        store.fence_epoch(999999, 1)


def test_stale_fence_rolls_back_whole_transaction(store):
    pk = store.create_process_node(NodeType.CALC_FUNCTION, "P")
    store.fence_epoch(pk, 2)
    # a zombie's unit of work: writes land in the txn, then its fence
    # assertion fails — EVERYTHING must roll back, not just the fence
    with pytest.raises(StaleEpochError):
        with store.transaction():
            store.update_process(pk, state="running")
            store.fence_epoch(pk, 1)
    node = store.get_node(pk)
    assert node["process_state"] != "running"


# ---------------------------------------------------------------------------
# fsck: detect + repair + idempotence
# ---------------------------------------------------------------------------

def _broker_db(tmp_path, *, lease_pks=()):
    db = str(tmp_path / "fsck-broker.db")
    conn = sqlite3.connect(db)
    conn.executescript(_TASKS_SCHEMA)
    for pk in lease_pks:
        conn.execute(
            "INSERT INTO leases (pk, worker, epoch, renewed_at)"
            " VALUES (?, 'w', 1, ?)", (pk, time.time()))
    conn.commit()
    conn.close()
    return db


def _task_pks(broker_db):
    conn = sqlite3.connect(broker_db)
    try:
        return sorted(
            json.loads(row[0])["pk"] for row in conn.execute(
                "SELECT payload FROM tasks WHERE state='ready'"))
    finally:
        conn.close()


def test_fsck_detects_and_repairs(store, tmp_path):
    from repro.chaos.invariants import check_store
    from repro.provenance.fsck import fsck

    # orphan with a checkpoint -> repair requeues it
    orphan_ckpt = store.create_process_node(NodeType.CALC_FUNCTION, "A")
    store.save_checkpoint(orphan_ckpt, {"pk": orphan_ckpt})
    # orphan without a checkpoint -> repair can only mark it excepted
    orphan_dead = store.create_process_node(NodeType.CALC_FUNCTION, "B")
    # held lease -> NOT an orphan, left alone
    live = store.create_process_node(NodeType.CALC_FUNCTION, "C")
    store.save_checkpoint(live, {"pk": live})
    # terminal process still carrying a checkpoint
    done = store.create_process_node(NodeType.CALC_FUNCTION, "D")
    store.update_process(done, state="finished", exit_status=0,
                         attributes={"state_history":
                                     [["finished", time.time()]]})
    store.save_checkpoint(done, {"pk": done})
    # dangling link
    with store._lock:
        store._conn().execute(
            "INSERT INTO links (in_id, out_id, link_type, label)"
            " VALUES (?, 999999, 'create', 'ghost')", (orphan_dead,))
        store._conn().commit()
    # unreferenced blob
    junk = store.repository.put(b"nobody references these bytes")

    broker_db = _broker_db(tmp_path, lease_pks=(live,))

    # -- detect-only: full census, nothing mutated
    report = fsck(store, broker_db=broker_db)
    assert report.counts() == {"orphan": 2, "stale-checkpoint": 1,
                               "dangling-link": 1, "unreferenced-blob": 1}
    assert store.repository.has(junk)
    assert store.load_checkpoint(done) is not None

    # -- repair
    repaired = fsck(store, repair=True, broker_db=broker_db)
    assert len(repaired.findings) == 5
    assert _task_pks(broker_db) == [orphan_ckpt]   # requeued
    node = store.get_node(orphan_dead)
    assert node["process_state"] == "excepted"
    assert node["exit_status"] == 999
    history = json.loads(node["attributes"])["state_history"]
    assert history[-1][0] == "excepted"
    assert store.load_checkpoint(done) is None
    assert not store.repository.has(junk)

    # -- idempotent: a second repair pass finds nothing (the requeued
    # orphan now has a pending task row, so it is no longer orphaned)
    assert fsck(store, repair=True, broker_db=broker_db).clean
    # and the repaired profile passes the chaos invariant checker
    assert check_store(store).ok


def test_fsck_without_broker_marks_orphans_excepted(store):
    from repro.provenance.fsck import fsck

    pk = store.create_process_node(NodeType.CALC_FUNCTION, "A")
    store.save_checkpoint(pk, {"pk": pk})
    report = fsck(store, repair=True, broker_db=None)
    assert report.counts() == {"orphan": 1}
    node = store.get_node(pk)
    # no broker to requeue into: even a checkpointed orphan goes terminal
    assert node["process_state"] == "excepted"
    assert node["checkpoint"] is None
    assert fsck(store, repair=True, broker_db=None).clean
