"""Import hypothesis if available, else degrade to skip-markers.

The property-based tests use only `given`, `settings` and `strategies as
st`. Without hypothesis installed, `given(...)` marks the test as skipped
(so the rest of each module still runs) and the strategy builders return
inert placeholders. With hypothesis installed (the `dev` extra), this
module is a pass-through.
"""

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert stand-in supporting chained builder calls."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    strategies = _Strategies()

st = strategies
