"""Provenance archives (ISSUE 4 tentpole): closure traversal, versioned
export/import with pk remapping and content-hash dedup, cross-profile
cache sharing, and the legacy-node hash backfill."""

import json
import os

import numpy as np
import pytest

from repro.caching import backfill_hashes, enable_caching
from repro.caching.registry import CacheRegistry
from repro.core import (
    ArrayData, Int, Process, ProcessSpec, ToContext, WorkChain,
    calcfunction,
)
from repro.provenance import (
    ArchiveError, ProvenanceStore, compute_closure, export_archive,
    import_archive, read_manifest,
)
from repro.provenance.store import LinkType, NodeType


# ---------------------------------------------------------------------------
# graph fixtures
# ---------------------------------------------------------------------------

def _data(store, value=0):
    from repro.core.datatypes import Int as IntData

    return store.store_data(IntData(value)).pk


def _proc(store, name="Calc", state="finished", exit_status=0,
          node_type=NodeType.CALC_FUNCTION, node_hash=None):
    pk = store.create_process_node(node_type, process_type=name,
                                   node_hash=node_hash)
    store.update_process(pk, state=state, exit_status=exit_status)
    return pk


def build_diamond(store):
    """d0 feeds calcs A and B; calc C consumes both of their outputs.

            d0
           /  \\
          A    B
          |    |
          dA   dB
           \\  /
            C
            |
            dC
    """
    d0 = _data(store, 0)
    a, b = _proc(store, "A"), _proc(store, "B")
    store.add_link(d0, a, LinkType.INPUT_CALC, "x")
    store.add_link(d0, b, LinkType.INPUT_CALC, "x")
    da, db = _data(store, 1), _data(store, 2)
    store.add_link(a, da, LinkType.CREATE, "out")
    store.add_link(b, db, LinkType.CREATE, "out")
    c = _proc(store, "C")
    store.add_link(da, c, LinkType.INPUT_CALC, "left")
    store.add_link(db, c, LinkType.INPUT_CALC, "right")
    dc = _data(store, 3)
    store.add_link(c, dc, LinkType.CREATE, "out")
    return {"d0": d0, "a": a, "b": b, "da": da, "db": db, "c": c, "dc": dc}


def build_workchains(store):
    """Two sibling workchains under one parent, each calling a calc."""
    parent = _proc(store, "Parent", node_type=NodeType.WORK_CHAIN)
    w1 = _proc(store, "W1", node_type=NodeType.WORK_CHAIN)
    w2 = _proc(store, "W2", node_type=NodeType.WORK_CHAIN)
    store.add_link(parent, w1, LinkType.CALL_WORK, "CALL_1")
    store.add_link(parent, w2, LinkType.CALL_WORK, "CALL_2")
    c1, c2 = _proc(store, "C1"), _proc(store, "C2")
    store.add_link(w1, c1, LinkType.CALL_CALC, "CALL_3")
    store.add_link(w2, c2, LinkType.CALL_CALC, "CALL_4")
    din = _data(store, 0)
    store.add_link(din, c1, LinkType.INPUT_CALC, "x")
    store.add_link(din, c2, LinkType.INPUT_CALC, "x")
    d1, d2 = _data(store, 1), _data(store, 2)
    store.add_link(c1, d1, LinkType.CREATE, "out")
    store.add_link(c2, d2, LinkType.CREATE, "out")
    store.add_link(w1, d1, LinkType.RETURN, "result")
    return {"parent": parent, "w1": w1, "w2": w2, "c1": c1, "c2": c2,
            "din": din, "d1": d1, "d2": d2}


# ---------------------------------------------------------------------------
# closure traversal
# ---------------------------------------------------------------------------

class TestClosure:
    def test_diamond_from_sink_pulls_all_ancestors(self, store):
        g = build_diamond(store)
        assert compute_closure(store, [g["c"]]) == set(g.values())

    def test_diamond_from_source_pulls_all_descendants(self, store):
        g = build_diamond(store)
        # d0's creators: none; its consumers are reached because the
        # descendant sweep starts from the selection's processes only —
        # a data seed alone must NOT drag in its consumers
        assert compute_closure(store, [g["d0"]]) == {g["d0"]}

    def test_process_seed_descends_and_closes_inputs(self, store):
        g = build_diamond(store)
        got = compute_closure(store, [g["a"]], ancestors=False)
        # A's inputs (always), its output, but not B's branch; C is not
        # reached because data nodes do not traverse to consumers
        assert got == {g["d0"], g["a"], g["da"]}

    def test_inputs_included_even_without_ancestors(self, store):
        g = build_diamond(store)
        got = compute_closure(store, [g["c"]], ancestors=False,
                              descendants=False)
        assert got == {g["c"], g["da"], g["db"]}

    def test_ancestors_only(self, store):
        g = build_diamond(store)
        got = compute_closure(store, [g["c"]], descendants=False)
        assert got == set(g.values()) - {g["dc"]}

    def test_workchain_seed_exports_whole_call_tree(self, store):
        g = build_workchains(store)
        assert compute_closure(store, [g["parent"]]) == set(g.values())

    def test_child_seed_pulls_caller_not_sibling(self, store):
        g = build_workchains(store)
        got = compute_closure(store, [g["c1"]], descendants=False)
        assert g["w1"] in got and g["parent"] in got
        assert g["c2"] not in got and g["w2"] not in got

    def test_sibling_reached_through_caller_descent(self, store):
        g = build_workchains(store)
        # with both directions on, the caller chain re-descends into the
        # sibling branch: the export is the full connected call tree
        assert compute_closure(store, [g["c1"]]) == set(g.values())

    def test_unknown_pk_raises(self, store):
        with pytest.raises(KeyError):
            compute_closure(store, [999])


# ---------------------------------------------------------------------------
# export / import round trip
# ---------------------------------------------------------------------------

@calcfunction
def add(a, b):
    return a + b


@calcfunction
def norm(arr):
    return ArrayData(np.linalg.norm(arr.value, axis=-1))


def _volatile(manifest):
    return {k: v for k, v in manifest.items() if k != "source"}


class TestRoundTrip:
    def test_manifest_counts(self, store, runner, tmp_path):
        add(Int(1), Int(2))
        manifest = export_archive(store, str(tmp_path / "a.zip"))
        assert manifest["archive_version"] == 1
        assert manifest["nodes"] == 4
        assert manifest["links"] == 3
        assert manifest["node_types"] == {"data": 3,
                                          "process.calcfunction": 1}

    def test_export_import_export_identical_manifests(self, store, runner,
                                                      tmp_path):
        add(Int(1), Int(2))
        add(Int(5), Int(6))
        norm(ArrayData(np.arange(12.0).reshape(3, 4)))
        m1 = export_archive(store, str(tmp_path / "a.zip"))

        target = ProvenanceStore(str(tmp_path / "b.db"))
        import_archive(target, str(tmp_path / "a.zip"))
        m2 = export_archive(target, str(tmp_path / "b.zip"))
        assert _volatile(m1) == _volatile(m2)
        assert m1["content_digest"] == m2["content_digest"]

    def test_random_graphs_round_trip(self, store, tmp_path):
        """Property-style: archives of randomly shaped DAGs survive the
        trip bit-identically (pk-free content digest)."""
        rng = np.random.default_rng(42)
        for trial in range(5):
            src = ProvenanceStore(":memory:")
            data = [_data(src, int(v)) for v in rng.integers(0, 99, 6)]
            for i in range(int(rng.integers(2, 6))):
                p = _proc(src, f"P{i}", node_hash=f"h{trial}-{i}")
                for d in rng.choice(data, 2, replace=False):
                    src.add_link(int(d), p, LinkType.INPUT_CALC,
                                 f"in{int(d)}")
                out = _data(src, i)
                src.add_link(p, out, LinkType.CREATE, "out")
                src.add_log(p, "REPORT", f"ran P{i}")
            a = str(tmp_path / f"t{trial}a.zip")
            b = str(tmp_path / f"t{trial}b.zip")
            m1 = export_archive(src, a)
            dst = ProvenanceStore(":memory:")
            import_archive(dst, a)
            m2 = export_archive(dst, b)
            assert _volatile(m1) == _volatile(m2)
            with open(a, "rb") as f1, open(b, "rb") as f2:
                assert f1.read() == f2.read()  # byte-identical zips

    def test_array_payload_round_trip(self, store, runner, tmp_path):
        arr = np.arange(24.0).reshape(4, 6)
        _res, node, _ec = norm.run_get_node(ArrayData(arr))
        export_archive(store, str(tmp_path / "a.zip"), [node.pk])
        target = ProvenanceStore(":memory:")
        result = import_archive(target, str(tmp_path / "a.zip"))
        proc_pk = result.pk_map[store.get_node(node.pk)["uuid"]]
        inputs = {label: target.load_data(pk)
                  for pk, _lt, label in target.incoming(proc_pk)}
        assert np.array_equal(inputs["arr"].value, arr)
        outputs = {label: target.load_data(pk)
                   for pk, _lt, label in target.outgoing(proc_pk)}
        assert np.allclose(outputs["result"].value,
                           np.linalg.norm(arr, axis=-1))

    def test_logs_and_attributes_travel(self, store, tmp_path):
        p = _proc(store, "Noisy")
        store.add_log(p, "REPORT", "hello from A")
        store.update_process(p, attributes={"custom": "kept"})
        export_archive(store, str(tmp_path / "a.zip"), [p])
        target = ProvenanceStore(":memory:")
        result = import_archive(target, str(tmp_path / "a.zip"))
        new_pk = result.pk_map[store.get_node(p)["uuid"]]
        logs = target.get_logs(new_pk)
        assert [(entry["levelname"], entry["message"]) for entry in logs] \
            == [("REPORT", "hello from A")]
        attrs = json.loads(target.get_node(new_pk)["attributes"])
        assert attrs["custom"] == "kept"

    def test_uuid_and_times_preserved(self, store, tmp_path):
        p = _proc(store, "Keeper")
        node = store.get_node(p)
        export_archive(store, str(tmp_path / "a.zip"), [p])
        target = ProvenanceStore(":memory:")
        result = import_archive(target, str(tmp_path / "a.zip"))
        imported = target.get_node(result.pk_map[node["uuid"]])
        assert imported["uuid"] == node["uuid"]
        assert imported["ctime"] == node["ctime"]
        assert imported["node_hash"] == node["node_hash"]

    def test_not_an_archive(self, store, tmp_path):
        bogus = tmp_path / "bogus.zip"
        import zipfile

        with zipfile.ZipFile(bogus, "w") as zf:
            zf.writestr("unrelated.txt", "nope")
        with pytest.raises(ArchiveError):
            read_manifest(str(bogus))

    def test_non_zip_and_missing_file_raise_archive_error(self, store,
                                                          tmp_path):
        not_zip = tmp_path / "plain.txt"
        not_zip.write_text("not a zip at all")
        with pytest.raises(ArchiveError):
            read_manifest(str(not_zip))
        with pytest.raises(ArchiveError):
            import_archive(store, str(tmp_path / "does_not_exist.zip"))

    def test_corrupt_archive_import_rolls_back(self, store, runner,
                                               tmp_path):
        """A missing payload member aborts the import atomically: the
        target store is left exactly as it was."""
        import zipfile

        arr = ArrayData(np.arange(6.0))
        _res, node, _ec = norm.run_get_node(arr)
        good = tmp_path / "good.zip"
        export_archive(store, str(good), [node.pk])
        bad = tmp_path / "bad.zip"
        with zipfile.ZipFile(good) as src, \
                zipfile.ZipFile(bad, "w") as dst:
            for info in src.infolist():
                if not info.filename.startswith("payloads/"):
                    dst.writestr(info, src.read(info))
        target = ProvenanceStore(":memory:")
        with pytest.raises(ArchiveError):
            import_archive(target, str(bad))
        assert target.count_nodes() == 0
        assert target._conn().execute(
            "SELECT COUNT(*) c FROM links").fetchone()["c"] == 0

    def test_version_gate(self, store, tmp_path):
        import zipfile

        bad = tmp_path / "future.zip"
        with zipfile.ZipFile(bad, "w") as zf:
            zf.writestr("manifest.json",
                        json.dumps({"archive_version": 99}))
        with pytest.raises(ArchiveError):
            import_archive(store, str(bad))


# ---------------------------------------------------------------------------
# import semantics: idempotence and dedup
# ---------------------------------------------------------------------------

class TestImportMerge:
    def test_reimport_is_noop(self, store, runner, tmp_path):
        add(Int(1), Int(2))
        export_archive(store, str(tmp_path / "a.zip"))
        target = ProvenanceStore(":memory:")
        first = import_archive(target, str(tmp_path / "a.zip"))
        again = import_archive(target, str(tmp_path / "a.zip"))
        assert first.nodes_imported == 4
        assert again.nodes_imported == 0
        assert again.nodes_existing == 4
        assert again.links_imported == 0
        assert target.count_nodes() == 4

    def test_hash_dedup_maps_to_existing_equivalent(self, store, runner,
                                                    tmp_path):
        """B already computed the same calculation: the archive node is
        not duplicated, its uuid maps onto B's own node."""
        _res, node, _ec = add.run_get_node(Int(1), Int(2))
        src_hash = store.get_node(node.pk)["node_hash"]
        assert src_hash
        export_archive(store, str(tmp_path / "a.zip"))

        # profile B independently ran the identical calc (same class,
        # same inputs -> same node_hash), under different uuids
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.store import configure_store

        target = configure_store(":memory:")
        set_default_runner(Runner(store=target))
        _res2, node_b, _ec2 = add.run_get_node(Int(1), Int(2))
        assert target.get_node(node_b.pk)["node_hash"] == src_hash
        before = target.count_nodes()

        result = import_archive(target, str(tmp_path / "a.zip"))
        assert result.nodes_deduped == 1
        assert result.pk_map[store.get_node(node.pk)["uuid"]] == node_b.pk
        # the deduped process's links were dropped, and its private
        # input/output data nodes — which would have imported with no
        # edges at all — were skipped with it: no orphan pollution
        assert result.links_imported == 0
        assert result.nodes_skipped_orphaned == 3
        assert target.count_nodes() == before

    def test_shared_input_of_deduped_calc_still_imports(self, store,
                                                        tmp_path):
        """A data node feeding both a deduped calc and a fresh calc must
        be imported (only its deduped-side link is dropped)."""
        shared = _data(store, 7)
        p1 = _proc(store, "Dup", node_hash="same")
        p2 = _proc(store, "Fresh", node_hash="other")
        store.add_link(shared, p1, LinkType.INPUT_CALC, "x")
        store.add_link(shared, p2, LinkType.INPUT_CALC, "x")
        export_archive(store, str(tmp_path / "a.zip"), [p1, p2],
                       descendants=False)

        target = ProvenanceStore(":memory:")
        _proc(target, "Dup", node_hash="same")  # pre-existing equivalent
        result = import_archive(target, str(tmp_path / "a.zip"))
        assert result.nodes_deduped == 1
        assert result.nodes_skipped_orphaned == 0
        shared_pk = result.pk_map[store.get_node(shared)["uuid"]]
        # exactly the fresh-side link survives
        assert [lt for _pk, lt, _l in target.outgoing(shared_pk)] \
            == [LinkType.INPUT_CALC.value]

    def test_no_dedup_flag_imports_duplicate(self, store, runner, tmp_path):
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.store import configure_store

        add.run_get_node(Int(1), Int(2))
        export_archive(store, str(tmp_path / "a.zip"))
        target = configure_store(":memory:")
        set_default_runner(Runner(store=target))
        add.run_get_node(Int(1), Int(2))
        result = import_archive(target, str(tmp_path / "a.zip"),
                                dedup=False)
        assert result.nodes_deduped == 0
        assert result.nodes_imported == 4

    def test_failed_nodes_are_not_dedup_targets(self, store, tmp_path):
        failed = _proc(store, "F", state="excepted", exit_status=1,
                       node_hash="hf")
        export_archive(store, str(tmp_path / "a.zip"), [failed])
        target = ProvenanceStore(":memory:")
        t = _proc(target, "F", state="excepted", exit_status=1,
                  node_hash="hf")
        result = import_archive(target, str(tmp_path / "a.zip"))
        assert result.nodes_deduped == 0
        assert result.nodes_imported == 1
        assert target.get_node(t) is not None

    def test_cached_from_pk_remapped(self, store, runner, tmp_path):
        _r1, n1, _e1 = add.run_get_node(Int(3), Int(4))
        with enable_caching():
            _r2, n2, _e2 = add.run_get_node(Int(3), Int(4))
        attrs = json.loads(store.get_node(n2.pk)["attributes"])
        assert attrs["cached_from_pk"] == n1.pk
        export_archive(store, str(tmp_path / "a.zip"))
        target = ProvenanceStore(":memory:")
        result = import_archive(target, str(tmp_path / "a.zip"),
                                dedup=False)
        clone_pk = result.pk_map[store.get_node(n2.pk)["uuid"]]
        src_pk = result.pk_map[store.get_node(n1.pk)["uuid"]]
        imported = json.loads(target.get_node(clone_pk)["attributes"])
        assert imported["cached_from"] == store.get_node(n1.pk)["uuid"]
        assert imported["cached_from_pk"] == src_pk


# ---------------------------------------------------------------------------
# the acceptance demo: cross-profile cache sharing
# ---------------------------------------------------------------------------

class Grind(Process):
    NODE_TYPE = NodeType.CALC_FUNCTION
    executions = 0

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("x", valid_type=Int, serializer=Int)
        spec.output("y", valid_type=Int)

    async def run(self):
        type(self).executions += 1
        self.out("y", Int(self.inputs["x"].value * 10))


@pytest.fixture(autouse=True)
def _reset_grind():
    Grind.executions = 0


class TestCrossProfileSharing:
    def test_imported_nodes_serve_cache_hits(self, store, runner, tmp_path):
        """Export finished-ok nodes from profile A, import into fresh B,
        relaunch in B with caching -> no recompute, `cached_from` points
        at the imported node."""
        from repro.engine.launch import run_get_node
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.store import configure_store

        _res, node_a = run_get_node(Grind, x=3)
        assert Grind.executions == 1
        a_uuid = store.get_node(node_a.pk)["uuid"]
        export_archive(store, str(tmp_path / "results.zip"), [node_a.pk])

        store_b = configure_store(str(tmp_path / "b.db"))
        set_default_runner(Runner(store=store_b))
        result = import_archive(store_b, str(tmp_path / "results.zip"))
        imported_pk = result.pk_map[a_uuid]

        with enable_caching(Grind):
            res_b, node_b = run_get_node(Grind, x=3)
        assert Grind.executions == 1, "imported result must short-circuit"
        assert res_b["y"].value == 30
        attrs = json.loads(store_b.get_node(node_b.pk)["attributes"])
        assert attrs["cached_from"] == a_uuid
        assert attrs["cached_from_pk"] == imported_pk
        # and it shows up in the registry's stats as a hit
        assert CacheRegistry(store_b).stats()["cache_hits"] == 1

    def test_different_inputs_still_compute(self, store, runner, tmp_path):
        from repro.engine.launch import run_get_node
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.store import configure_store

        _res, node_a = run_get_node(Grind, x=3)
        export_archive(store, str(tmp_path / "results.zip"), [node_a.pk])
        store_b = configure_store(":memory:")
        set_default_runner(Runner(store=store_b))
        import_archive(store_b, str(tmp_path / "results.zip"))
        with enable_caching(Grind):
            res, _node = run_get_node(Grind, x=4)
        assert Grind.executions == 2, "different fingerprint: no hit"
        assert res["y"].value == 40


# ---------------------------------------------------------------------------
# hash backfill
# ---------------------------------------------------------------------------

def _wipe_hashes(store):
    """Simulate a legacy pre-caching profile."""
    store._conn().execute("UPDATE nodes SET node_hash=NULL")
    store._conn().commit()


class TestBackfill:
    def test_legacy_node_becomes_cache_hittable(self, store, runner):
        from repro.engine.launch import run_get_node

        run_get_node(Grind, x=5)
        _wipe_hashes(store)
        with enable_caching(Grind):
            run_get_node(Grind, x=5)
        assert Grind.executions == 2, "no hash, no hit"
        _wipe_hashes(store)  # both nodes are now hash-less "legacy" rows

        stats = backfill_hashes(store, classes={"Grind": Grind})
        assert stats.hashed == 2 and stats.scanned == 2
        with enable_caching(Grind):
            _res, node = run_get_node(Grind, x=5)
        assert Grind.executions == 2, "backfilled node now serves the hit"
        attrs = json.loads(store.get_node(node.pk)["attributes"])
        assert "cached_from" in attrs

    def test_backfilled_hash_matches_fresh_launch_hash(self, store, runner):
        from repro.engine.launch import run_get_node

        _res, node = run_get_node(Grind, x=7)
        fresh = store.get_node(node.pk)["node_hash"]
        _wipe_hashes(store)
        backfill_hashes(store, classes={"Grind": Grind})
        assert store.get_node(node.pk)["node_hash"] == fresh

    def test_idempotent(self, store, runner):
        from repro.engine.launch import run_get_node

        run_get_node(Grind, x=1)
        _wipe_hashes(store)
        first = backfill_hashes(store, classes={"Grind": Grind})
        second = backfill_hashes(store, classes={"Grind": Grind})
        assert first.hashed == 1
        assert second.scanned == 0 and second.hashed == 0

    def test_dry_run_writes_nothing(self, store, runner):
        from repro.engine.launch import run_get_node

        _res, node = run_get_node(Grind, x=2)
        _wipe_hashes(store)
        before = {r["pk"]: (r["attributes"], r["mtime"])
                  for r in store._conn().execute("SELECT * FROM nodes")}
        stats = backfill_hashes(store, classes={"Grind": Grind},
                                dry_run=True)
        assert stats.hashed == 1 and stats.dry_run
        assert store.get_node(node.pk)["node_hash"] is None
        assert store.get_meta("cache_backfill.hashed") is None
        assert store.get_meta("cache_backfill.runs") is None
        # a dry run must not touch the database at all
        after = {r["pk"]: (r["attributes"], r["mtime"])
                 for r in store._conn().execute("SELECT * FROM nodes")}
        assert after == before

    def test_unresolvable_type_counted_not_fatal(self, store):
        _proc(store, "NoSuchClassAnywhere")
        stats = backfill_hashes(store)
        assert stats.skipped_unresolvable == 1 and stats.hashed == 0

    def test_invalidated_nodes_respected(self, store, runner):
        from repro.engine.launch import run_get_node

        _res, node = run_get_node(Grind, x=9)
        CacheRegistry(store).invalidate(pk=node.pk)
        stats = backfill_hashes(store, classes={"Grind": Grind})
        assert stats.skipped_invalidated == 1
        assert store.get_node(node.pk)["node_hash"] is None
        stats = backfill_hashes(store, classes={"Grind": Grind},
                                include_invalidated=True)
        assert stats.hashed == 1
        assert store.get_node(node.pk)["node_hash"]

    def test_workchains_not_backfilled(self, store, runner):
        class Chain(WorkChain):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("x", valid_type=Int, serializer=Int)
                spec.outline(cls.step)

            def step(self):
                pass

        from repro.engine.launch import run_get_node

        run_get_node(Chain, x=1)
        _wipe_hashes(store)
        stats = backfill_hashes(store, classes={"Chain": Chain})
        assert stats.scanned == 0

    def test_batched_progress(self, store, runner):
        from repro.engine.launch import run_get_node

        for i in range(5):
            run_get_node(Grind, x=i)
        _wipe_hashes(store)
        messages = []
        stats = backfill_hashes(store, classes={"Grind": Grind},
                                batch_size=2, progress=messages.append)
        assert stats.hashed == 5
        assert len(messages) == 3  # ceil(5/2) batches reported

    def test_namespaced_inputs_rehash_correctly(self, store, runner):
        """Backfill must un-flatten `ns__key` link labels through the
        port tree so the recomputed hash matches a fresh launch."""

        class Nested(Process):
            NODE_TYPE = NodeType.CALC_FUNCTION

            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input_namespace("params")
                spec.input("params.alpha", valid_type=Int, serializer=Int)
                spec.input("params.beta", valid_type=Int, serializer=Int)
                spec.input("x", valid_type=Int, serializer=Int)
                spec.output("y", valid_type=Int)

            async def run(self):
                self.out("y", Int(self.inputs["x"].value))

        from repro.engine.launch import run_get_node

        _res, node = run_get_node(
            Nested, {"params": {"alpha": 1, "beta": 2}, "x": 3})
        fresh = store.get_node(node.pk)["node_hash"]
        assert fresh
        _wipe_hashes(store)
        stats = backfill_hashes(store, classes={"Nested": Nested})
        assert stats.hashed == 1
        assert store.get_node(node.pk)["node_hash"] == fresh


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

class TestArchiveCli:
    @pytest.fixture()
    def profile(self, tmp_path):
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.store import configure_store

        db = str(tmp_path / "a.db")
        st = configure_store(db)
        set_default_runner(Runner(store=st))

        @calcfunction
        def plus(a, b):
            return a + b

        plus(Int(1), Int(2))
        st.close()
        set_default_runner(None)
        return db

    def test_create_inspect_import(self, profile, tmp_path, capsys):
        from repro import cli

        archive = str(tmp_path / "out.zip")
        cli.main(["-p", profile, "archive", "create", "-o", archive,
                  "--all"])
        out = capsys.readouterr().out
        assert "wrote" in out and "4 node(s)" in out

        cli.main(["-p", profile, "archive", "inspect", archive])
        out = capsys.readouterr().out
        assert "archive version 1" in out and "process.calcfunction" in out

        target = str(tmp_path / "b.db")
        cli.main(["-p", target, "archive", "import", archive])
        out = capsys.readouterr().out
        assert "imported 4 node(s)" in out

        cli.main(["-p", target, "archive", "import", archive])
        out = capsys.readouterr().out
        assert "nothing new to import" in out

    def test_create_requires_selection(self, profile, tmp_path):
        from repro import cli

        with pytest.raises(SystemExit):
            cli.main(["-p", profile, "archive", "create", "-o",
                      str(tmp_path / "x.zip")])

    def test_backfill_cli(self, profile, capsys):
        from repro import cli
        from repro.provenance.store import ProvenanceStore

        st = ProvenanceStore(profile)
        _wipe_hashes(st)
        st.close()
        cli.main(["-p", profile, "cache", "backfill", "--dry-run"])
        out = capsys.readouterr().out
        assert "would hash" in out
