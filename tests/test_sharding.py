"""Sharding-rule resolution + an actual 8-device lowering in a subprocess
(the main test process keeps the single CPU device)."""

import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config
from repro.distributed import sharding as sh


class FakeMesh:
    """Just enough mesh for rule resolution (axis_names + devices.shape)."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_rules_head_tp_arch():
    cfg = get_config("deepseek-67b")
    mesh = FakeMesh((16, 16), ("data", "model"))
    rules = sh.make_rules(cfg, mesh, fsdp=True)
    assert rules["heads"] == "model"
    assert rules["embed"] == ("data",)
    assert rules["seq_sharded"] is None          # head-TP archs don't seq-shard


def test_rules_seq_parallel_arch():
    cfg = get_config("qwen2-0.5b")
    mesh = FakeMesh((16, 16), ("data", "model"))
    rules = sh.make_rules(cfg, mesh, fsdp=False)
    assert rules["heads"] is None                 # 14 heads can't shard 16 ways
    assert rules["seq_sharded"] == "model"
    assert rules["embed"] is None                 # fsdp off => replicated


def test_rules_moe_strategies():
    mesh = FakeMesh((16, 16), ("data", "model"))
    ep = sh.make_rules(get_config("moonshot-v1-16b-a3b"), mesh)
    assert ep["expert_sharded"] == "model" and ep["moe_ffn"] is None
    tp = sh.make_rules(get_config("grok-1-314b"), mesh)
    assert tp["expert_sharded"] is None and tp["moe_ffn"] == "model"


def test_divisibility_fallback_replicates():
    from jax.sharding import PartitionSpec as P
    notes = []
    spec = sh.resolve_spec((7, 128), ("batch", "ffn"),
                           {"batch": ("data",), "ffn": "model"},
                           {"data": 16, "model": 16}, notes, "w")
    assert spec == P(None, "model")               # 7 % 16 != 0 -> replicated
    assert notes and "7" in notes[0]


def test_multi_pod_batch_axes():
    cfg = get_config("qwen3-4b")
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    rules = sh.make_rules(cfg, mesh, fsdp=True, fsdp_over_pod=True)
    assert rules["batch"] == ("pod", "data")
    assert rules["embed"] == ("pod", "data")


SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import json
    import jax
    from repro.launch import dryrun as dr

    # shrink the production mesh for the in-test lowering
    import repro.launch.mesh as mesh_mod
    def small_mesh(*, multi_pod=False):
        if multi_pod:
            return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        return jax.make_mesh((2, 4), ("data", "model"))
    mesh_mod.make_production_mesh = small_mesh
    dr.make_production_mesh = small_mesh

    # reduced config so the compile is fast
    from repro.configs import reduced_config
    import repro.launch.dryrun as d2
    d2.get_config = lambda a: reduced_config(a)

    res = dr.lower_cell({arch!r}, {shape!r}, multi_pod={multi!r})
    print("RESULT:" + json.dumps({{
        "ok": "error" not in res and not res.get("skipped"),
        "collectives": res.get("collectives", {{}}).get("counts"),
    }}))
""")


@pytest.mark.parametrize("arch,shape,multi", [
    ("qwen3-4b", "train_4k", False),
    ("moonshot-v1-16b-a3b", "train_4k", True),
    ("recurrentgemma-2b", "decode_32k", False),
])
def test_real_lowering_on_8_fake_devices(arch, shape, multi):
    import os
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prog = SUBPROCESS_PROG.format(src=os.path.abspath(src), arch=arch,
                                  shape=shape, multi=multi)
    proc = subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    result = json.loads(line[0][len("RESULT:"):])
    assert result["ok"], proc.stdout
