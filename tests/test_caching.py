"""Content-addressed process caching (ISSUE 1 tentpole).

Covers: hash stability and sensitivity, cache-hit output cloning with
`cached_from` provenance, policy scoping (context manager, env var,
per-type), invalidation, the CalcJob scheduler-skip fast path and a
daemon-worker cache hit across OS processes."""

import json
import time

import numpy as np
import pytest

from repro.caching import (
    CacheRegistry, compute_input_hash, disable_caching, enable_caching,
    get_policy, hash_data_value,
)
from repro.core import (
    ArrayData, Bool, Dict, Float, FolderData, Int, List, Process,
    ProcessSpec, Str, WorkChain, calcfunction, workfunction,
)
from repro.provenance.store import LinkType, NodeType

TERMINAL = ("finished", "excepted", "killed")


class Doubler(Process):
    NODE_TYPE = NodeType.CALC_FUNCTION
    executions = 0

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("x", valid_type=Int)
        spec.output("y", valid_type=Int)

    async def run(self):
        type(self).executions += 1
        self.out("y", Int(self.inputs["x"].value * 2))


@pytest.fixture(autouse=True)
def _reset_counter():
    Doubler.executions = 0


# ---------------------------------------------------------------------------
# hashing: stability and sensitivity
# ---------------------------------------------------------------------------

class TestHashing:
    def test_same_inputs_same_hash(self, store):
        h1 = compute_input_hash(Doubler, {"x": Int(3)})
        h2 = compute_input_hash(Doubler, {"x": Int(3)})
        assert h1 == h2

    def test_changed_port_value_changes_hash(self, store):
        assert compute_input_hash(Doubler, {"x": Int(3)}) != \
            compute_input_hash(Doubler, {"x": Int(4)})

    def test_value_type_is_part_of_hash(self, store):
        assert hash_data_value(Int(1)) != hash_data_value(Float(1.0))
        assert hash_data_value(Bool(True)) != hash_data_value(Int(1))

    def test_scalar_hashes_stable_across_instances(self):
        for make in (lambda: Int(7), lambda: Float(2.5), lambda: Str("a"),
                     lambda: Bool(True), lambda: Dict({"k": [1, 2]}),
                     lambda: List([1, "x"])):
            assert hash_data_value(make()) == hash_data_value(make())

    def test_array_hash_covers_dtype_shape_bytes(self):
        a = ArrayData(np.arange(6, dtype=np.float32))
        same = ArrayData(np.arange(6, dtype=np.float32))
        assert hash_data_value(a) == hash_data_value(same)
        # any changed byte
        flipped = np.arange(6, dtype=np.float32)
        flipped[3] += 1e-6
        assert hash_data_value(a) != hash_data_value(ArrayData(flipped))
        # dtype
        assert hash_data_value(a) != \
            hash_data_value(ArrayData(np.arange(6, dtype=np.float64)))
        # shape (same bytes)
        assert hash_data_value(ArrayData(np.zeros((2, 3)))) != \
            hash_data_value(ArrayData(np.zeros((3, 2))))

    def test_folder_hash(self):
        f1 = FolderData({"a.txt": b"xx", "b.txt": b"yy"})
        f2 = FolderData({"b.txt": b"yy", "a.txt": b"xx"})
        assert hash_data_value(f1) == hash_data_value(f2)
        assert hash_data_value(f1) != \
            hash_data_value(FolderData({"a.txt": b"xy", "b.txt": b"yy"}))

    def test_process_version_salts_hash(self, store, monkeypatch):
        h1 = compute_input_hash(Doubler, {"x": Int(3)})
        monkeypatch.setattr(Doubler, "CACHE_VERSION", 2)
        assert compute_input_hash(Doubler, {"x": Int(3)}) != h1

    def test_same_name_different_module_distinct(self, store):
        class Doppel(Doubler):
            pass

        Doppel.__name__ = Doubler.__name__
        Doppel.__qualname__ = Doubler.__qualname__
        Doppel.__module__ = "somewhere.else"
        assert compute_input_hash(Doubler, {"x": Int(3)}) != \
            compute_input_hash(Doppel, {"x": Int(3)})

    def test_process_type_in_hash(self, store):
        class Tripler(Doubler):
            pass

        assert compute_input_hash(Doubler, {"x": Int(3)}) != \
            compute_input_hash(Tripler, {"x": Int(3)})

    def test_metadata_and_non_db_excluded(self, store):
        class WithMeta(Doubler):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("opts", valid_type=dict, non_db=True,
                           required=False, default=dict)

        h1 = compute_input_hash(WithMeta, {"x": Int(1), "opts": {"a": 1},
                                           "metadata": {"label": "l1"}})
        h2 = compute_input_hash(WithMeta, {"x": Int(1), "opts": {"a": 2},
                                           "metadata": {"label": "l2"}})
        assert h1 == h2

    def test_function_source_salts_hash(self, store):
        @calcfunction
        def body_a(x):
            return x.value + 1

        @calcfunction
        def body_b(x):
            return x.value + 2

        body_b.process_class.__name__ = body_a.process_class.__name__
        assert compute_input_hash(body_a.process_class, {"x": Int(1)}) != \
            compute_input_hash(body_b.process_class, {"x": Int(1)})

    def test_exclude_from_hash_port_not_fingerprinted(self, store):
        """Ports declared exclude_from_hash (tolerances/thresholds) do not
        affect the cache fingerprint, while normal ports do."""

        class Tolerant(Process):
            NODE_TYPE = NodeType.CALC_FUNCTION
            executions = 0

            @classmethod
            def define(cls, spec: ProcessSpec) -> None:
                super().define(spec)
                spec.input("x", valid_type=Int)
                spec.input("tol", valid_type=Float, required=False,
                           exclude_from_hash=True)
                spec.output("y", valid_type=Int)

            async def run(self):
                type(self).executions += 1
                self.out("y", Int(self.inputs["x"].value * 2))

        h_base = compute_input_hash(Tolerant, {"x": Int(1),
                                               "tol": Float(1e-6)})
        h_tol = compute_input_hash(Tolerant, {"x": Int(1),
                                              "tol": Float(1e-3)})
        h_x = compute_input_hash(Tolerant, {"x": Int(2),
                                            "tol": Float(1e-6)})
        assert h_base == h_tol      # tolerance change: same fingerprint
        assert h_base != h_x        # real input change: different

        # end to end: a different tolerance still takes the cache hit,
        # and the tolerance IS linked in provenance (unlike non_db)
        from repro.engine.runner import default_runner
        runner = default_runner()
        with enable_caching():
            _, p1 = runner.run(Tolerant, {"x": Int(5), "tol": Float(1e-6)})
            _, p2 = runner.run(Tolerant, {"x": Int(5), "tol": Float(1e-3)})
        assert Tolerant.executions == 1
        attrs = json.loads(store.get_node(p2.pk)["attributes"])
        assert attrs["cached_from_pk"] == p1.pk
        labels = {lbl for _, _, lbl in store.incoming(p2.pk)}
        assert "tol" in labels

    def test_nested_metadata_key_is_hashed(self, store):
        class DynIn(Doubler):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.inputs.dynamic = True

        h1 = compute_input_hash(DynIn, {"x": Int(1),
                                        "cfg": {"metadata": Str("v1")}})
        h2 = compute_input_hash(DynIn, {"x": Int(1),
                                        "cfg": {"metadata": Str("v2")}})
        assert h1 != h2   # only the *top-level* metadata ns is excluded

    def test_hash_persisted_on_node(self, store, runner):
        outputs, proc = runner.run(Doubler, {"x": Int(5)})
        node = store.get_node(proc.pk)
        assert node["node_hash"] == proc._input_hash
        assert node["node_hash"] is not None


# ---------------------------------------------------------------------------
# cache hits: cloning + provenance
# ---------------------------------------------------------------------------

class TestCacheHit:
    def test_hit_skips_execution_and_clones_outputs(self, store, runner):
        with enable_caching():
            out1, p1 = runner.run(Doubler, {"x": Int(21)})
            out2, p2 = runner.run(Doubler, {"x": Int(21)})
        assert Doubler.executions == 1
        assert p2.is_finished_ok
        assert out2["y"].value == 42
        # outputs are fresh nodes, not shared with the original
        assert out2["y"].pk != out1["y"].pk
        # linked with the normal CREATE edge
        created = store.outgoing(p2.pk, LinkType.CREATE)
        assert [(lbl, pk) for pk, _, lbl in created] == [("y", out2["y"].pk)]

    def test_cached_from_metadata(self, store, runner):
        with enable_caching():
            _, p1 = runner.run(Doubler, {"x": Int(1)})
            _, p2 = runner.run(Doubler, {"x": Int(1)})
        attrs = json.loads(store.get_node(p2.pk)["attributes"])
        assert attrs["cached_from_pk"] == p1.pk
        assert attrs["cached_from"] == store.get_node(p1.pk)["uuid"]
        src = store.get_node(p1.pk)
        assert src["process_state"] == "finished"
        assert src["exit_status"] == 0
        # the original was computed, not cloned
        assert "cached_from" not in json.loads(src["attributes"])

    def test_miss_on_different_inputs(self, store, runner):
        with enable_caching():
            runner.run(Doubler, {"x": Int(1)})
            _, p2 = runner.run(Doubler, {"x": Int(2)})
        assert Doubler.executions == 2
        assert "cached_from" not in \
            json.loads(store.get_node(p2.pk)["attributes"])

    def test_failed_processes_are_not_cache_sources(self, store, runner):
        class Flaky(Doubler):
            fail = True

            async def run(self):
                type(self).executions += 1
                if type(self).fail:
                    return 7
                self.out("y", Int(self.inputs["x"].value * 2))

        with enable_caching():
            _, p1 = runner.run(Flaky, {"x": Int(1)})
            assert p1.exit_code.status == 7
            Flaky.fail = False
            _, p2 = runner.run(Flaky, {"x": Int(1)})
        assert Flaky.executions == 2   # failure was not reused
        assert p2.is_finished_ok

    def test_calcfunction_hit_returns_cloned_result(self, store, runner):
        calls = []

        @calcfunction
        def add(a, b):
            calls.append(1)
            return a.value + b.value

        with enable_caching():
            r1 = add(Int(2), Int(3))
            r2 = add(Int(2), Int(3))
        assert len(calls) == 1
        assert r1.value == r2.value == 5
        assert r2.pk != r1.pk

    def test_calcfunction_hit_preserves_dict_return_shape(self, store,
                                                          runner):
        @calcfunction
        def wrapped(x):
            return {"result": Int(x.value + 1)}

        @calcfunction
        def multi(x):
            return {"a": Int(x.value), "b": Int(-x.value)}

        with enable_caching():
            cold = wrapped(Int(1))
            warm = wrapped(Int(1))
            assert isinstance(cold, dict) and isinstance(warm, dict)
            assert warm["result"].value == 2

            cold_m = multi(Int(3))
            warm_m = multi(Int(3))
        assert set(cold_m) == set(warm_m) == {"a", "b"}
        assert warm_m["a"].value == 3 and warm_m["b"].value == -3

    def test_flat_label_containing_dunder_stays_flat(self, store, runner):
        @calcfunction
        def dyn(x):
            return {"a__b": Int(x.value)}

        with enable_caching():
            cold = dyn(Int(4))
            warm = dyn(Int(4))
        assert set(cold) == set(warm) == {"a__b"}
        assert warm["a__b"].value == 4

    def test_run_get_node_shape_on_hit(self, store, runner):
        @calcfunction
        def pair(x):
            return {"a": x.value, "b": x.value + 1}

        with enable_caching():
            r1, p1, _ = pair.run_get_node(Int(1))
            r2, p2, _ = pair.run_get_node(Int(1))
        assert isinstance(r1, Dict) and isinstance(r2, Dict)
        assert r1.value == r2.value == {"a": 1, "b": 2}
        assert "cached_from" in json.loads(
            store.get_node(p2.pk)["attributes"])

    def test_invalidate_stops_reuse(self, store, runner):
        reg = CacheRegistry(store)
        with enable_caching():
            _, p1 = runner.run(Doubler, {"x": Int(9)})
            assert reg.invalidate(pk=p1.pk) == 1
            runner.run(Doubler, {"x": Int(9)})
        assert Doubler.executions == 2

    def test_invalidate_by_process_type(self, store, runner):
        with enable_caching():
            runner.run(Doubler, {"x": Int(1)})
            runner.run(Doubler, {"x": Int(2)})
            n = CacheRegistry(store).invalidate(process_type="Doubler")
            assert n == 2
            runner.run(Doubler, {"x": Int(1)})
        assert Doubler.executions == 3

    def test_stats(self, store, runner):
        reg = CacheRegistry(store)
        with enable_caching():
            runner.run(Doubler, {"x": Int(1)})
            runner.run(Doubler, {"x": Int(1)})
            runner.run(Doubler, {"x": Int(2)})
        s = reg.stats()
        row = s["process_types"]["Doubler"]
        assert row["hashed_nodes"] == 3
        assert row["distinct_hashes"] == 2
        assert row["cache_hits"] == 1


# ---------------------------------------------------------------------------
# policy scoping
# ---------------------------------------------------------------------------

class TestPolicy:
    def test_caching_off_by_default(self, store, runner):
        runner.run(Doubler, {"x": Int(3)})
        runner.run(Doubler, {"x": Int(3)})
        assert Doubler.executions == 2

    def test_enable_caching_scopes(self, store, runner):
        with enable_caching():
            runner.run(Doubler, {"x": Int(3)})
            runner.run(Doubler, {"x": Int(3)})
        assert Doubler.executions == 1
        runner.run(Doubler, {"x": Int(3)})   # outside the scope
        assert Doubler.executions == 2

    def test_enable_caching_for_specific_type(self, store, runner):
        class Other(Doubler):
            executions = 0

        with enable_caching("Other"):
            runner.run(Doubler, {"x": Int(1)})
            runner.run(Doubler, {"x": Int(1)})
            runner.run(Other, {"x": Int(1)})
            runner.run(Other, {"x": Int(1)})
        assert Doubler.executions == 2
        assert Other.executions == 1

    def test_disable_overrides_inner_scope(self, store, runner):
        with enable_caching():
            with disable_caching(Doubler):
                runner.run(Doubler, {"x": Int(1)})
                runner.run(Doubler, {"x": Int(1)})
        assert Doubler.executions == 2

    def test_env_var_enables(self, store, runner, monkeypatch):
        monkeypatch.setenv("REPRO_CACHING", "1")
        runner.run(Doubler, {"x": Int(1)})
        runner.run(Doubler, {"x": Int(1)})
        assert Doubler.executions == 1

    def test_env_var_type_list(self, store, runner, monkeypatch):
        class Other(Doubler):
            executions = 0

        monkeypatch.setenv("REPRO_CACHING", "Other,SomethingElse")
        runner.run(Doubler, {"x": Int(1)})
        runner.run(Doubler, {"x": Int(1)})
        runner.run(Other, {"x": Int(1)})
        runner.run(Other, {"x": Int(1)})
        assert Doubler.executions == 2
        assert Other.executions == 1

    def test_policy_object_opt_in(self, store, runner):
        get_policy().enable("Doubler")
        runner.run(Doubler, {"x": Int(1)})
        runner.run(Doubler, {"x": Int(1)})
        assert Doubler.executions == 1

    def test_workflows_never_cached(self, store, runner):
        ran = []

        class Chain(WorkChain):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("x", valid_type=Int)
                spec.output("y", valid_type=Int)
                spec.outline(cls.go)

            def go(self):
                ran.append(1)
                self.out("y", Int(self.inputs["x"].value))

        with enable_caching():
            runner.run(Chain, {"x": Int(1)})
            runner.run(Chain, {"x": Int(1)})
        assert len(ran) == 2

        @workfunction
        def orchestrate(x):
            ran.append(1)
            return x

        with enable_caching():
            orchestrate(Int(1))
            orchestrate(Int(1))
        assert len(ran) == 4

    def test_cacheable_false_opts_out(self, store, runner):
        class NonDeterministic(Doubler):
            CACHEABLE = False
            executions = 0

        with enable_caching():
            runner.run(NonDeterministic, {"x": Int(1)})
            runner.run(NonDeterministic, {"x": Int(1)})
        assert NonDeterministic.executions == 2


# ---------------------------------------------------------------------------
# hash-collision telemetry (same fingerprint, different outputs)
# ---------------------------------------------------------------------------

class TestCollisionTelemetry:
    def _make_hidden_input_cls(self):
        class HiddenInput(Process):
            """Output depends on class state the fingerprint cannot see —
            the canonical way a hash collision arises in practice."""
            NODE_TYPE = NodeType.CALC_FUNCTION
            bump = 0

            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("x", valid_type=Int)
                spec.output("y", valid_type=Int)

            async def run(self):
                self.out("y", Int(self.inputs["x"].value + HiddenInput.bump))

        return HiddenInput

    def test_collision_counted_on_hit_path(self, store, runner):
        HiddenInput = self._make_hidden_input_cls()
        # two cold runs, same fingerprint, different outputs
        runner.run(HiddenInput, {"x": Int(1)})
        HiddenInput.bump = 100
        runner.run(HiddenInput, {"x": Int(1)})

        registry = CacheRegistry(store)
        with enable_caching():
            _, proc = runner.run(HiddenInput, {"x": Int(1)})
        assert proc.is_finished_ok
        counts = registry.collision_counts()
        assert counts.get("HiddenInput") == 1
        assert registry.stats()["hash_collisions"] == 1
        per_type = registry.stats()["process_types"]["HiddenInput"]
        assert per_type["hash_collisions"] == 1

    def test_no_collision_when_outputs_agree(self, store, runner):
        runner.run(Doubler, {"x": Int(2)})
        runner.run(Doubler, {"x": Int(2)})
        registry = CacheRegistry(store)
        with enable_caching():
            runner.run(Doubler, {"x": Int(2)})
        assert registry.collision_counts() == {}
        assert registry.stats()["hash_collisions"] == 0

    def test_counter_is_durable_and_cumulative(self, store, runner):
        HiddenInput = self._make_hidden_input_cls()
        runner.run(HiddenInput, {"x": Int(1)})
        HiddenInput.bump = 7
        runner.run(HiddenInput, {"x": Int(1)})
        with enable_caching():
            runner.run(HiddenInput, {"x": Int(1)})
            runner.run(HiddenInput, {"x": Int(1)})
        # each cache-hit lookup that saw the mismatch counts once
        assert CacheRegistry(store).collision_counts()["HiddenInput"] >= 2


# ---------------------------------------------------------------------------
# CalcJob fast path: no scheduler submission on a hit
# ---------------------------------------------------------------------------

class TestCalcJobCaching:
    def test_hit_skips_scheduler_entirely(self, store, runner):
        from repro.calcjobs.calcjob import CalcInfo, CalcJob, get_cluster
        from repro.core import FolderData, Str

        class EchoJob(CalcJob):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("text", valid_type=Str)
                spec.output("echoed", valid_type=Str)

            def prepare_for_submission(self):
                return CalcInfo(
                    files={"in.txt": self.inputs["text"].value.encode()},
                    executable="echo", retrieve_list=["in.txt"])

            def parse(self, retrieved: FolderData):
                self.out("echoed",
                         Str(retrieved.get_bytes("in.txt").decode()))

        get_cluster(runner).register_executable(
            "echo", lambda inputs: dict(inputs))

        async def drive(proc):
            return await proc.step_until_terminated()

        with enable_caching():
            p1 = EchoJob({"text": Str("hello")}, runner=runner)
            runner.run_until_complete(drive(p1))
            assert p1.is_finished_ok
            n_jobs_after_first = len(get_cluster(runner).jobs)
            assert n_jobs_after_first >= 1

            p2 = EchoJob({"text": Str("hello")}, runner=runner)
            runner.run_until_complete(drive(p2))
        assert p2.is_finished_ok
        assert p2.outputs["echoed"].value == "hello"
        # no new scheduler job, no new upload
        assert len(get_cluster(runner).jobs) == n_jobs_after_first
        attrs = json.loads(store.get_node(p2.pk)["attributes"])
        assert attrs["cached_from_pk"] == p1.pk
        # the retrieved folder was cloned too
        labels = {lbl for _, _, lbl in store.outgoing(p2.pk,
                                                      LinkType.CREATE)}
        assert labels == {"retrieved", "echoed"}


# ---------------------------------------------------------------------------
# daemon: a worker in another OS process takes the fast path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_daemon_worker_cache_hit_skips_execution(tmp_path, monkeypatch):
    from repro.calcjobs import TPUTrainJob
    from repro.engine.daemon import Daemon
    from repro.provenance.store import configure_store

    monkeypatch.setenv("REPRO_CACHING", "TPUTrainJob")
    cfg = {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8,
           "seed": 0}

    daemon = Daemon(str(tmp_path), workers=1, slots=8)
    daemon.start()
    try:
        store = configure_store(daemon.store_path)

        def wait(pk, timeout=150):
            t0 = time.time()
            while time.time() - t0 < timeout:
                node = store.get_node(pk)
                if node and node["process_state"] in TERMINAL:
                    return node
                daemon.supervise()
                time.sleep(0.3)
            raise TimeoutError(f"process {pk} did not finish")

        pk1 = daemon.submit(TPUTrainJob, {"config": Dict(cfg)})
        n1 = wait(pk1)
        assert n1["process_state"] == "finished" and n1["exit_status"] == 0

        t0 = time.time()
        pk2 = daemon.submit(TPUTrainJob, {"config": Dict(cfg)})
        n2 = wait(pk2)
        warm = time.time() - t0
        assert n2["process_state"] == "finished" and n2["exit_status"] == 0
        attrs = json.loads(n2["attributes"])
        assert attrs["cached_from_pk"] == pk1
        # executed runs log upload/submit reports; a cache hit only logs
        # the hit itself — proof the worker skipped execution
        messages = " ".join(l["message"] for l in store.get_logs(pk2))
        assert "cache hit" in messages
        assert "submitted as job" not in messages
        assert warm < 30
    finally:
        daemon.stop()
