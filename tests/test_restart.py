"""BaseRestartWorkChain driven through injected exit codes and chaos
faults: handlers fire, inputs change on retry, iteration budgets exhaust,
and killed/excepted children are retried instead of read as success."""

import pytest

from repro.calcjobs.restart import (
    BaseRestartWorkChain, HandlerReport, process_handler,
)
from repro.chaos import faults
from repro.chaos.faults import ChaosPlan
from repro.core import Int, Process
from repro.core.process import ProcessKilled
from repro.provenance.store import NodeType


@pytest.fixture(autouse=True)
def _disarm_chaos():
    faults.deactivate()
    yield
    faults.deactivate()


class BrittleCalc(Process):
    """Fails with exit 310 until its ``good`` input flips to 1 — the
    knob a process handler turns on retry."""

    NODE_TYPE = NodeType.CALC_FUNCTION
    CACHEABLE = False

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("good", valid_type=Int, default=Int(0))
        spec.output("value", valid_type=Int)
        spec.exit_code(310, "ERROR_BAD_INPUT", "the input was bad")

    async def run(self):
        if not self.inputs["good"].value:
            return self.exit_codes.ERROR_BAD_INPUT
        self.out("value", Int(42))


class SuicidalCalc(Process):
    """Dies by kill (no exit code recorded) while ``armed``."""

    NODE_TYPE = NodeType.CALC_FUNCTION
    CACHEABLE = False

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("armed", valid_type=Int, default=Int(1))
        spec.output("value", valid_type=Int)

    async def run(self):
        if self.inputs["armed"].value:
            raise ProcessKilled("chaos kill")
        self.out("value", Int(7))


class BrittleRestart(BaseRestartWorkChain):
    _process_class = BrittleCalc

    @process_handler(310)
    def handle_bad_input(self, child):
        # modify the retry's inputs — the canonical handler move
        self.ctx.process_inputs["good"] = Int(1)
        self.report("bad input handled: flipping 'good' for the retry")
        return None


def test_handler_fires_and_modifies_inputs(store, runner):
    outputs, proc = runner.run(BrittleRestart, {"good": Int(0)})
    assert proc.is_finished_ok
    assert proc.ctx.iteration == 2
    assert outputs["value"].value == 42
    # first child failed with the injected status, second succeeded
    first, second = proc.ctx.children
    assert first.exit_status == 310
    assert second.is_finished_ok


class NeverHealsRestart(BaseRestartWorkChain):
    _process_class = BrittleCalc

    @process_handler(310)
    def handle_plain_retry(self, child):
        return None  # retry without changing anything — stays broken


def test_max_iterations_exhausted(store, runner):
    outputs, proc = runner.run(NeverHealsRestart, {
        "good": Int(0), "max_iterations": Int(2)})
    assert not proc.is_finished_ok
    assert proc.exit_code.status == 401
    assert proc.ctx.iteration == 2


def test_unhandled_exit_code_is_unrecoverable(store, runner):
    class NoHandlers(BaseRestartWorkChain):
        _process_class = BrittleCalc

    outputs, proc = runner.run(NoHandlers, {"good": Int(0)})
    assert not proc.is_finished_ok
    assert proc.exit_code.status == 402


class SuicideRestart(BaseRestartWorkChain):
    _process_class = SuicidalCalc

    # killed children record exit status 998; excepted ones record nothing
    # and surface as the synthetic EXIT_STATUS_DIED
    @process_handler(998, BaseRestartWorkChain.EXIT_STATUS_DIED)
    def handle_dead_child(self, child):
        assert child.process_state in ("killed", "excepted")
        self.ctx.process_inputs["armed"] = Int(0)
        self.report("dead child handled: disarming the retry")
        return None


def test_killed_child_restarted_cleanly(store, runner):
    """A child that dies without an exit code (killed) must not read as
    success — the handler disarms it and the retry completes."""
    outputs, proc = runner.run(SuicideRestart, {"armed": Int(1)})
    assert proc.is_finished_ok
    assert proc.ctx.iteration == 2
    assert outputs["value"].value == 7
    assert proc.ctx.children[0].process_state == "killed"


class ChaosChildRestart(BaseRestartWorkChain):
    _process_class = BrittleCalc

    @process_handler(BaseRestartWorkChain.EXIT_STATUS_DIED)
    def handle_dead_child(self, child):
        return None  # plain retry; the chaos rule only fires once

    @process_handler(310)
    def handle_bad_input(self, child):
        self.ctx.process_inputs["good"] = Int(1)
        return None


def test_chaos_excepted_child_restarted_cleanly(store, runner):
    """Inject a one-shot fault into the first child's terminal step via
    the chaos registry; the child excepts, the handler retries it."""
    faults.activate(ChaosPlan(seed=1).on("process.terminal.pre", "raise",
                                         nth=1))
    outputs, proc = runner.run(ChaosChildRestart, {"good": Int(1)})
    faults.deactivate()
    assert proc.is_finished_ok
    assert outputs["value"].value == 42
    assert proc.ctx.children[0].process_state == "excepted"
    assert proc.ctx.iteration == 2


def test_handler_report_exit_code_short_circuits(store, runner):
    class GiveUpRestart(BaseRestartWorkChain):
        _process_class = BrittleCalc

        @process_handler(310)
        def handle_fatal(self, child):
            from repro.core import ExitCode
            return HandlerReport(
                do_break=True,
                exit_code=ExitCode(402, "declared unrecoverable"))

    outputs, proc = runner.run(GiveUpRestart, {"good": Int(0)})
    assert proc.exit_code.status == 402
    assert proc.ctx.iteration == 1
