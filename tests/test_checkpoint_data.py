"""Sharded model checkpoints (elastic restore, async) + data pipeline
determinism and exact resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, TokenStream


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "step": jnp.asarray(7),
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,))},
    }
    path = ckpt.save_checkpoint(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored = ckpt.restore_checkpoint(str(tmp_path), target=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for step in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(str(tmp_path), step, state, max_to_keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_4", "step_5"]


def test_async_checkpointer_overlaps(tmp_path):
    state = {"x": jnp.arange(1000.0)}
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(1, state)
    ac.save(2, state)     # barriers on the first
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 2
    restored = ckpt.restore_checkpoint(str(tmp_path), target=state)
    np.testing.assert_allclose(np.asarray(restored["x"]),
                               np.asarray(state["x"]))


def test_data_pipeline_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=64, batch_size=4, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    for _ in range(3):
        b1, b2 = s1.next_batch(), s2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # labels are tokens shifted by one position
    full1 = np.concatenate([b1["tokens"], b1["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])


def test_data_pipeline_exact_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=32, batch_size=2, seed=5)
    s1 = TokenStream(cfg)
    for _ in range(2):
        s1.next_batch()
    cursor = s1.state_dict()
    expected = s1.next_batch()

    s2 = TokenStream(cfg)
    s2.load_state_dict(cursor)
    resumed = s2.next_batch()
    np.testing.assert_array_equal(expected["tokens"], resumed["tokens"])


def test_data_pipeline_host_sharding_disjoint():
    """Different hosts consume disjoint document streams."""
    kw = dict(vocab_size=500, seq_len=32, batch_size=2, seed=1, num_hosts=2)
    h0 = TokenStream(DataConfig(host_id=0, **kw))
    h1 = TokenStream(DataConfig(host_id=1, **kw))
    b0, b1 = h0.next_batch(), h1.next_batch()
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint saved unsharded restores onto explicit shardings (the
    single-device analogue of resuming on a different mesh size)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save_checkpoint(str(tmp_path), 1, state)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = ckpt.restore_checkpoint(str(tmp_path), target=state,
                                       shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))
