"""Extended-FSM invariants (paper §III.B fig. 6), incl. hypothesis walks."""

import pytest
from _hypothesis_compat import given, strategies as st

from repro.core.statemachine import (
    InvalidTransitionError, ProcessState, StateMachine, TERMINAL_STATES,
    TRANSITIONS,
)


class Recorder(StateMachine):
    def __init__(self):
        super().__init__()
        self.events = []

    def on_exiting(self):
        self.events.append(("exiting", self.state))

    def on_entering(self, state):
        self.events.append(("entering", state))

    def on_entered(self, from_state):
        self.events.append(("entered", from_state, self.state))


def test_happy_path_hook_order():
    sm = Recorder()
    sm.transition_to(ProcessState.RUNNING)
    assert sm.events == [
        ("exiting", ProcessState.CREATED),
        ("entering", ProcessState.RUNNING),
        ("entered", ProcessState.CREATED, ProcessState.RUNNING),
    ]


def test_terminal_states_allow_nothing():
    for terminal in TERMINAL_STATES:
        assert TRANSITIONS[terminal] == frozenset()


def test_invalid_transition_raises_and_preserves_state():
    sm = Recorder()
    with pytest.raises(InvalidTransitionError):
        sm.transition_to(ProcessState.FINISHED)   # CREATED -/-> FINISHED
    assert sm.state is ProcessState.CREATED


def test_pause_resume_returns_to_interrupted_state():
    sm = Recorder()
    sm.transition_to(ProcessState.RUNNING)
    sm.transition_to(ProcessState.WAITING)
    sm.transition_to(ProcessState.PAUSED)
    assert sm.resume_from_pause() is ProcessState.WAITING


@given(st.lists(st.sampled_from(list(ProcessState)), max_size=12))
def test_random_walk_respects_transition_table(targets):
    """Any sequence of attempted transitions either follows the table or
    raises, and the machine never leaves a terminal state."""
    sm = Recorder()
    for tgt in targets:
        current = sm.state
        if tgt in TRANSITIONS[current]:
            sm.transition_to(tgt)
            assert sm.state is tgt
        else:
            with pytest.raises(InvalidTransitionError):
                sm.transition_to(tgt)
            assert sm.state is current
        if sm.is_terminated:
            assert sm.state in TERMINAL_STATES


@given(st.lists(st.sampled_from(list(ProcessState)), max_size=12))
def test_entered_hook_fires_exactly_once_per_transition(targets):
    sm = Recorder()
    transitions = 0
    for tgt in targets:
        if tgt in TRANSITIONS[sm.state]:
            sm.transition_to(tgt)
            transitions += 1
    entered = [e for e in sm.events if e[0] == "entered"]
    assert len(entered) == transitions
