"""CalcJob lifecycle (upload/submit/update/retrieve), fault injection,
pause-not-except, error handlers (paper §II.B.4 + fig. 3)."""

import pytest

from repro.calcjobs import TPUTrainJob
from repro.calcjobs.calcjob import get_cluster
from repro.calcjobs.restart import (
    BaseRestartWorkChain, HandlerReport, process_handler,
)
from repro.core import Dict, Int
from repro.engine.transport import FlakyTransport
from repro.provenance.store import LinkType, NodeType, QueryBuilder

SMALL = {"arch": "qwen2-0.5b", "steps": 2, "batch": 1, "seq": 16}


def test_tpu_train_job_happy_path(store, runner):
    outputs, proc = runner.run(TPUTrainJob, {"config": Dict(SMALL)})
    assert proc.is_finished_ok
    metrics = outputs["metrics"].value
    assert metrics["steps"] == 2
    assert all(l > 0 for l in metrics["losses"])
    # retrieved folder linked as output
    outs = store.outgoing(proc.pk, LinkType.CREATE)
    assert {label for _, _, label in outs} >= {"retrieved", "metrics"}


def test_transport_faults_recovered_by_backoff(store, runner):
    cluster = get_cluster(runner)
    flaky = FlakyTransport(fail_first=2, hostname="flaky")
    flaky.command_handler = cluster.handle_command
    flaky.files = cluster.filesystems.setdefault("flaky", {})
    runner.transport_queue.register_transport(flaky)

    outputs, proc = runner.run(TPUTrainJob, {
        "config": Dict(SMALL), "metadata": {"computer": "flaky"}})
    assert proc.is_finished_ok
    # every stage hit the injected failures yet the job finished
    assert flaky._failures["put"] == 2
    assert flaky._failures["exec:sbatch"] == 2


def test_scheduler_job_failure_maps_to_exit_code(store, runner):
    cluster = get_cluster(runner)
    cluster.fail_rate = 1.0   # every job fails on the cluster
    outputs, proc = runner.run(TPUTrainJob, {"config": Dict(SMALL)})
    assert not proc.is_finished_ok
    assert proc.exit_code.status == 100
    cluster.fail_rate = 0.0


def test_nan_loss_exit_code(store, runner):
    cfg = dict(SMALL)
    cfg["inject_nan"] = True
    outputs, proc = runner.run(TPUTrainJob, {"config": Dict(cfg)})
    assert proc.exit_code.status == 310


class TPURestart(BaseRestartWorkChain):
    _process_class = TPUTrainJob

    @process_handler(310)
    def handle_nan(self, child):
        cfg = dict(self.ctx.process_inputs["config"].value)
        cfg["inject_nan"] = False
        cfg["lr"] = cfg.get("lr", 3e-4) / 10
        self.ctx.process_inputs["config"] = Dict(cfg)
        self.report("NaN handled: lr lowered")
        return None

    @process_handler(100)
    def handle_scheduler(self, child):
        self.report("scheduler failure: plain retry")
        return None


def test_restart_workchain_recovers_nan(store, runner):
    cfg = dict(SMALL)
    cfg["inject_nan"] = True
    outputs, proc = runner.run(TPURestart, {"config": Dict(cfg)})
    assert proc.is_finished_ok
    assert proc.ctx.iteration == 2
    assert "metrics" in outputs


def test_restart_workchain_gives_up_after_max_iterations(store, runner):
    cluster = get_cluster(runner)
    cluster.fail_rate = 1.0
    outputs, proc = runner.run(TPURestart, {
        "config": Dict(SMALL), "max_iterations": Int(2)})
    assert not proc.is_finished_ok
    assert proc.exit_code.status == 401
    assert proc.ctx.iteration == 2
    cluster.fail_rate = 0.0


def test_unhandled_exit_code_is_unrecoverable(store, runner):
    class NoHandlers(BaseRestartWorkChain):
        _process_class = TPUTrainJob

    cfg = dict(SMALL)
    cfg["inject_nan"] = True     # 310 with no handler registered
    outputs, proc = runner.run(NoHandlers, {"config": Dict(cfg)})
    assert proc.exit_code.status == 402


def test_calcjob_checkpoints_record_stage(store, runner):
    outputs, proc = runner.run(TPUTrainJob, {"config": Dict(SMALL)})
    # terminal processes have their checkpoint deleted, but stages were
    # persisted along the way — verify via the reports/logs trail
    logs = store.get_logs(proc.pk)
    msgs = " ".join(l["message"] for l in logs)
    assert "uploaded" in msgs and "submitted" in msgs
