"""Engine saturation machinery (paper §III.C at scale): batched
control-plane traffic, subject-filter pushdown, multiplexed process
ownership, backpressure + fair dispatch, RPC deadlines, event-log
compaction, and slot-gated process materialization."""

import asyncio
import time

import pytest

from repro.core.process import Process
from repro.engine.broker import BrokerClient, BrokerServer, SyncBrokerClient
from repro.engine.communicator import process_rpc_id, state_subject
from repro.engine.daemon import PROCESS_QUEUE, Daemon, make_process_task_handler
from repro.engine.runner import Runner
from repro.observability import metrics as _metrics
from repro.provenance.store import configure_store


class Spin(Process):
    async def run(self):
        for _ in range(5000):
            await self._pause_point()
            await self.interruptible(asyncio.sleep(0.01))


class Quick(Process):
    async def run(self):
        await asyncio.sleep(0.05)


def run(coro, timeout=60):
    loop = asyncio.new_event_loop()
    try:
        return loop.run_until_complete(asyncio.wait_for(coro, timeout))
    finally:
        loop.close()


async def _server(tmp_path, **kw):
    server = BrokerServer(str(tmp_path / "broker.db"), **kw)
    await server.start()
    return server


async def _client(server):
    client = BrokerClient(server.host, server.port)
    await client.connect()
    return client


async def _settle(predicate, timeout=5.0, interval=0.01):
    t0 = time.monotonic()
    while not predicate():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition never settled")
        await asyncio.sleep(interval)


# ---------------------------------------------------------------------------
# batched submission (task_send_many) + the persistent daemon submitter
# ---------------------------------------------------------------------------

def test_task_send_many_delivers_each_exactly_once(tmp_path):
    async def main():
        server = await _server(tmp_path)
        producer = await _client(server)
        consumer = await _client(server)
        seen = []

        async def handle(payload):
            seen.append(payload["i"])

        consumer.add_task_subscriber("q", handle, prefetch=64)
        producer.task_send_many("q", [{"i": i} for i in range(25)])
        await _settle(lambda: len(seen) == 25)
        await asyncio.sleep(0.1)            # no late duplicates
        assert sorted(seen) == list(range(25))
        assert server.stats["tasks_enqueued"] == 25
        assert server.stats["tasks_delivered"] == 25
        producer.close()
        consumer.close()
        await server.stop()

    run(main())


def test_sync_client_batch_send_is_acked_durably(tmp_path):
    async def main():
        server = await _server(tmp_path)

        def sync_part():
            client = SyncBrokerClient(server.host, server.port)
            try:
                assert client.task_send_many(
                    "q", [{"i": i} for i in range(7)]) == 7
                client.task_send("q", {"i": 99})     # single-send ack path
            finally:
                client.close()

        await asyncio.get_running_loop().run_in_executor(None, sync_part)
        # the ack means the rows were committed before the reply
        rows = server.conn().execute(
            "SELECT COUNT(*) c FROM tasks WHERE queue='q'").fetchone()
        assert rows["c"] == 8
        await server.stop()

    run(main())


def test_daemon_submitter_is_one_persistent_connection(tmp_path):
    daemon = Daemon(str(tmp_path / "d"), workers=0, slots=1)
    daemon.start()
    try:
        store = configure_store(str(tmp_path / "d" / "provenance.db"))
        runner = Runner(store=store)
        pks = [Quick(inputs={}, runner=runner).pk for _ in range(3)]
        daemon.send_task(pks[0])
        first = daemon._submit_client
        assert first is not None
        assert daemon.send_tasks(pks[1:]) == 2
        # same connection reused; every send was acked (durable enqueue)
        assert daemon._submit_client is first
        stats = first.broker_stats()
        assert stats["tasks_enqueued"] == 3
        assert stats["queues"][PROCESS_QUEUE]["ready"] == 3
    finally:
        daemon.stop()


# ---------------------------------------------------------------------------
# subject-filter pushdown + broadcast batching
# ---------------------------------------------------------------------------

def test_subject_filter_pushdown_spares_uninterested_clients(tmp_path):
    async def main():
        server = await _server(tmp_path)
        emitter = await _client(server)
        interested = await _client(server)
        bystander = await _client(server)

        got, stray = [], []
        interested.add_broadcast_subscriber(
            lambda s, _, b: got.append(s), "state_changed.7.*")
        # the bystander never subscribes: with filter pushdown the broker
        # must not send it any broadcast frame at all
        bystander._broadcast_handlers[0] = (None,
                                            lambda s, _, b: stray.append(s))
        await asyncio.sleep(0.05)
        baseline_out = server.stats["messages_out"]

        emitter.broadcast_send(state_subject(7, "finished"), 7, {"pk": 7})
        emitter.broadcast_send(state_subject(8, "finished"), 8, {"pk": 8})
        await _settle(lambda: got == ["state_changed.7.finished"])
        await asyncio.sleep(0.1)
        assert stray == []
        # exactly one frame left the broker: the matching event to the
        # one interested client (nothing to the emitter or bystander)
        assert server.stats["messages_out"] - baseline_out == 1
        for c in (emitter, interested, bystander):
            c.close()
        await server.stop()

    run(main())


def test_broadcast_burst_coalesces_into_batch_frames(tmp_path):
    async def main():
        server = await _server(tmp_path)
        emitter = await _client(server)
        watcher = await _client(server)
        got = []
        watcher.add_broadcast_subscriber(lambda s, _, b: got.append(s),
                                         "state_changed.*")
        await asyncio.sleep(0.05)
        baseline_out = server.stats["messages_out"]
        n = 40
        for pk in range(n):
            emitter.broadcast_send(state_subject(pk, "finished"), pk,
                                   {"pk": pk})
        await _settle(lambda: len(got) == n)
        # a same-tick burst must reach the watcher in far fewer frames
        # than events (coalesced broadcast_batch), not one frame each
        frames = server.stats["messages_out"] - baseline_out
        assert frames < n / 4, f"{frames} frames for {n} events"
        emitter.close()
        watcher.close()
        await server.stop()

    run(main())


# ---------------------------------------------------------------------------
# multiplexed process ownership (O(workers) directory)
# ---------------------------------------------------------------------------

def test_process_control_is_multiplexed_not_per_pk(tmp_path):
    async def main():
        server = await _server(tmp_path)
        worker = await _client(server)
        control = await _client(server)
        store = configure_store(":memory:")
        runner = Runner(store=store, communicator=worker)
        handles = [runner.submit(Spin, {}) for _ in range(5)]
        pks = [h.pk for h in handles]
        await _settle(lambda: len(server._owners) == 5)

        # the broker directory holds NO per-pk rpc identifiers — just the
        # ownership map — yet per-pk lookup and rpc_send still work
        assert not any(i.startswith("process.") for i in server._rpc)
        found = await control.rpc_lookup("process.*")
        assert set(found) == {f"process.{pk}" for pk in pks}
        status = await control.rpc_send_async(process_rpc_id(pks[0]),
                                              {"intent": "status"})
        assert status["state"] == "running"
        assert await control.rpc_send_async(
            process_rpc_id(pks[0]), {"intent": "kill"}) is True
        await asyncio.wait_for(handles[0].process.wait_done(), 10)
        await _settle(lambda: len(server._owners) == 4)
        for h in handles[1:]:
            await control.rpc_send_async(process_rpc_id(h.pk),
                                         {"intent": "kill"})
            await asyncio.wait_for(h.process.wait_done(), 10)
        worker.close()
        control.close()
        await server.stop()

    run(main())


# ---------------------------------------------------------------------------
# backpressure: the prefetch high-water mark parks excess durably
# ---------------------------------------------------------------------------

def test_prefetch_hwm_bounds_inflight_and_parks_the_rest(tmp_path):
    async def main():
        server = await _server(tmp_path)
        producer = await _client(server)
        consumer = await _client(server)
        cur, peak, done, parked = 0, 0, [], []

        async def handle(payload):
            nonlocal cur, peak
            cur += 1
            peak = max(peak, cur)
            # while 2 are in flight, the rest must sit parked as durable
            # ready rows, not in this client's memory
            parked.append(server.conn().execute(
                "SELECT COUNT(*) c FROM tasks WHERE state='ready'"
            ).fetchone()["c"])
            await asyncio.sleep(0.01)
            cur -= 1
            done.append(payload["i"])

        consumer.add_task_subscriber("q", handle, prefetch=2)
        producer.task_send_many("q", [{"i": i} for i in range(20)])
        await _settle(lambda: len(done) == 20)
        await asyncio.sleep(0.05)
        assert sorted(done) == list(range(20))       # exactly once
        assert peak <= 2, f"prefetch=2 but {peak} handlers ran at once"
        assert max(parked) >= 10                     # backlog stayed parked
        producer.close()
        consumer.close()
        await server.stop()

    run(main())


def test_slot_gate_bounds_resident_processes(tmp_path):
    """Tasks delivered beyond the slot count wait as pk-only payloads:
    Process objects are only materialized once a slot frees (bounds
    worker RSS at saturation)."""
    async def main():
        _metrics.reset_registry()
        store = configure_store(":memory:")
        runner = Runner(store=store, slots=2)
        pks = [Quick(inputs={}, runner=runner).pk for _ in range(6)]
        owned = set()
        handler = make_process_task_handler(runner, store, owned)
        gauge = _metrics.get_registry().gauge("daemon.resident_processes")
        peak = 0

        async def watch():
            nonlocal peak
            while True:
                peak = max(peak, gauge.value)
                await asyncio.sleep(0.002)

        watcher = asyncio.ensure_future(watch())
        await asyncio.gather(*[handler({"pk": pk}) for pk in pks])
        watcher.cancel()
        assert peak == 2, f"slots=2 but {peak} processes were resident"
        for pk in pks:
            assert store.get_node(pk)["process_state"] == "finished"

    run(main())


# ---------------------------------------------------------------------------
# fairness: a bulk submitter cannot starve a trickle submitter
# ---------------------------------------------------------------------------

def test_trickle_submitter_not_starved_by_bulk_backlog(tmp_path):
    async def main():
        server = await _server(tmp_path)
        producer = await _client(server)
        consumer = await _client(server)
        order = []

        async def handle(payload):
            await asyncio.sleep(0.005)
            order.append(payload["who"])

        consumer.add_task_subscriber("q", handle, prefetch=1)
        producer.task_send_many("q", [{"who": "bulk", "i": i}
                                      for i in range(40)],
                                submitter="bulk")
        await asyncio.sleep(0.02)            # bulk backlog is queued first
        producer.task_send_many("q", [{"who": "trickle", "i": i}
                                      for i in range(4)],
                                submitter="trickle")
        await _settle(lambda: len(order) == 44, timeout=20)
        # round-robin across submitters: the trickle tasks complete long
        # before the bulk backlog drains instead of queueing behind it
        last_trickle = max(i for i, who in enumerate(order)
                           if who == "trickle")
        assert last_trickle < 24, (
            f"trickle task finished at position {last_trickle}/44")
        assert order.count("trickle") == 4 and order.count("bulk") == 40
        producer.close()
        consumer.close()
        await server.stop()

    run(main())


# ---------------------------------------------------------------------------
# RPC deadlines: a hung handler cannot wedge the caller (or the worker)
# ---------------------------------------------------------------------------

def test_rpc_deadline_cancels_hung_handler(tmp_path):
    async def main():
        server = await _server(tmp_path)
        worker = await _client(server)
        control = await _client(server)
        cancelled = asyncio.Event()

        async def hung(msg):
            try:
                await asyncio.sleep(3600)
            except asyncio.CancelledError:
                cancelled.set()
                raise

        worker.add_rpc_subscriber("svc.hung", hung)
        worker.add_rpc_subscriber("svc.ok", lambda msg: "fine")
        await asyncio.sleep(0.05)

        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            await control.rpc_send_async("svc.hung", {}, timeout=0.3)
        assert time.monotonic() - t0 < 5.0
        assert server.stats["rpc_cancelled"] == 1
        # the broker told the worker to abandon the handler task
        await asyncio.wait_for(cancelled.wait(), 5)
        # neither side is wedged: the same client/worker pair still works
        assert await control.rpc_send_async("svc.ok", {}) == "fine"
        worker.close()
        control.close()
        await server.stop()

    run(main())


# ---------------------------------------------------------------------------
# event-log compaction: terminal notifications survive the cap
# ---------------------------------------------------------------------------

def test_compaction_drops_superseded_not_terminal_events(tmp_path):
    async def main():
        server = await _server(tmp_path, event_log_cap=20)
        emitter = await _client(server)
        for pk in range(15):
            for state in ("created", "running", "finished"):
                emitter.broadcast_send(state_subject(pk, state), pk,
                                       {"pk": pk, "state": state})
        await _settle(lambda: server.stats["events_logged"] == 45)
        await asyncio.sleep(0.05)
        subjects = [r["subject"] for r in server.conn().execute(
            "SELECT subject FROM events ORDER BY seq")]
        assert len(subjects) <= 20 + 5   # cap, modulo the check interval
        # every terminal notification survived; the chatter it supersedes
        # was evicted first
        for pk in range(15):
            assert state_subject(pk, "finished") in subjects
        assert server.stats["events_compacted"] > 0
        assert sum(1 for s in subjects if s.endswith(".running")) < 15

        # a late watcher still learns every terminal outcome by replay
        def sync_part():
            client = SyncBrokerClient(server.host, server.port)
            try:
                return [b["pk"] for _, _, b in client.events(
                    subject_filter="state_changed.*.finished",
                    timeout=1.0, replay_since=0)]
            finally:
                client.close()

        replayed = await asyncio.get_running_loop().run_in_executor(
            None, sync_part)
        assert sorted(replayed) == list(range(15))
        emitter.close()
        await server.stop()

    run(main())
