"""The event-driven control plane (paper §III.A, §III.C): remote RPC
process control forwarded through the broker, event-driven waits (no poll
loop), durable kills that survive worker restarts, and the live
state-change event stream."""

import asyncio
import json
import time

import pytest

from repro.core.process import Process
from repro.engine.broker import BrokerClient, BrokerServer, SyncBrokerClient
from repro.engine.communicator import (
    parse_state_subject, process_rpc_id, state_subject,
)
from repro.engine.daemon import make_process_task_handler
from repro.engine.runner import Runner
from repro.provenance.store import NodeType, configure_store

TERMINAL = ("finished", "excepted", "killed")


class Spin(Process):
    """Runs 'forever' in small interruptible slices — a control target."""

    async def run(self):
        for _ in range(5000):
            await self._pause_point()
            await self.interruptible(asyncio.sleep(0.01))


class Quick(Process):
    async def run(self):
        await asyncio.sleep(0.05)


def run(coro, timeout=60):
    loop = asyncio.new_event_loop()
    try:
        return loop.run_until_complete(asyncio.wait_for(coro, timeout))
    finally:
        loop.close()


async def _broker_pair(tmp_path):
    """A broker + two connected clients (one 'worker', one 'control')."""
    server = BrokerServer(str(tmp_path / "broker.db"))
    host, port = await server.start()
    worker = BrokerClient(host, port)
    await worker.connect()
    control = BrokerClient(host, port)
    await control.connect()
    return server, worker, control


async def _status_until(control, pk, want, attempts=200):
    for _ in range(attempts):
        status = await control.rpc_send_async(process_rpc_id(pk),
                                              {"intent": "status"})
        if status["state"] == want:
            return status
        await asyncio.sleep(0.02)
    raise AssertionError(f"process {pk} never reached {want!r}: {status}")


# ---------------------------------------------------------------------------
# subject / identifier scheme
# ---------------------------------------------------------------------------

def test_subject_scheme_roundtrip():
    assert state_subject(42, "finished") == "state_changed.42.finished"
    assert parse_state_subject("state_changed.42.finished") == (42, "finished")
    assert parse_state_subject("unrelated.42.finished") is None
    assert parse_state_subject("state_changed.nan.x") is None
    assert process_rpc_id(7) == "process.7"


# ---------------------------------------------------------------------------
# remote control through the broker (cross-client RPC forwarding)
# ---------------------------------------------------------------------------

def test_remote_pause_play_kill_through_broker(tmp_path):
    async def main():
        _, worker, control = await _broker_pair(tmp_path)
        store = configure_store(":memory:")
        runner_w = Runner(store=store, communicator=worker)
        handle = runner_w.submit(Spin, {})
        pk = handle.pk
        await asyncio.sleep(0.1)   # let the process start + register RPC

        assert await control.rpc_send_async(
            process_rpc_id(pk), {"intent": "pause"}) is True
        status = await _status_until(control, pk, "paused")
        assert status["paused"] is True
        assert store.get_node(pk)["process_state"] == "paused"

        assert await control.rpc_send_async(
            process_rpc_id(pk), {"intent": "play"}) is True
        await _status_until(control, pk, "running")

        assert await control.rpc_send_async(
            process_rpc_id(pk), {"intent": "kill", "message": "bye"}) is True
        await asyncio.wait_for(handle.process.wait_done(), 10)
        assert handle.process.state.value == "killed"
        node = store.get_node(pk)
        assert node["process_state"] == "killed"
        # the kill was recorded durably before it was executed
        assert json.loads(node["attributes"])["kill_requested"] == "bye"

    run(main())


def test_rpc_to_unknown_process_errors(tmp_path):
    async def main():
        _, _, control = await _broker_pair(tmp_path)
        with pytest.raises(KeyError):
            await control.rpc_send_async(process_rpc_id(404),
                                         {"intent": "status"})

    run(main())


def test_rpc_directory_lookup_and_sync_client(tmp_path):
    async def main():
        server, worker, control = await _broker_pair(tmp_path)
        worker.add_rpc_subscriber("worker.abc",
                                  lambda msg: {"pks": [1, 2], "slots": 4})
        worker.add_rpc_subscriber(process_rpc_id(7),
                                  lambda msg: {"state": "running"})
        await asyncio.sleep(0.05)
        assert await control.rpc_lookup("process.*") == ["process.7"]
        assert await control.rpc_lookup("worker.*") == ["worker.abc"]

        def sync_part():
            client = SyncBrokerClient(server.host, server.port)
            try:
                assert client.lookup("worker.*") == ["worker.abc"]
                assert client.rpc("worker.abc", {})["pks"] == [1, 2]
                with pytest.raises(KeyError):
                    client.rpc("process.404", {})
            finally:
                client.close()

        await asyncio.get_running_loop().run_in_executor(None, sync_part)

        # unregistering removes the directory entry
        worker.remove_rpc_subscriber(process_rpc_id(7))
        await asyncio.sleep(0.05)
        assert await control.rpc_lookup("process.*") == []

    run(main())


# ---------------------------------------------------------------------------
# event-driven waits (the no-poll-loop claim)
# ---------------------------------------------------------------------------

def test_runner_has_no_poll_interval():
    assert not hasattr(Runner(store=configure_store(":memory:")),
                       "poll_interval")


def test_remote_wait_is_event_driven(tmp_path):
    """A waiter with no local handle completes via the terminal broadcast
    well under the old 2 s poll floor."""

    async def main():
        _, worker, waiter = await _broker_pair(tmp_path)
        store = configure_store(":memory:")
        runner_w = Runner(store=store, communicator=worker)
        runner_c = Runner(store=store, communicator=waiter)
        handle = runner_w.submit(Quick, {})
        assert handle.pk not in runner_c._processes   # remote path

        t0 = time.monotonic()
        node = await runner_c.wait(handle.pk)
        elapsed = time.monotonic() - t0
        assert node["process_state"] == "finished"
        # the process itself sleeps 0.05 s; anything close to the old
        # 2 s poll interval means we are polling again
        assert elapsed < 1.0, f"wait took {elapsed:.3f}s — not event-driven"

    run(main())


def test_wait_all_waits_concurrently(tmp_path):
    async def main():
        _, worker, waiter = await _broker_pair(tmp_path)
        store = configure_store(":memory:")
        runner_w = Runner(store=store, communicator=worker)
        runner_c = Runner(store=store, communicator=waiter)
        handles = [runner_w.submit(Quick, {}) for _ in range(5)]
        t0 = time.monotonic()
        nodes = await runner_c.wait_all([h.pk for h in handles])
        elapsed = time.monotonic() - t0
        assert [n["process_state"] for n in nodes] == ["finished"] * 5
        # five concurrent 0.05 s processes must not take 5 × the serial time
        assert elapsed < 1.0

    run(main())


def test_wait_liveness_fallback_catches_silent_termination():
    """A worker that dies without broadcasting: the coarse store re-check
    (NOT a poll loop — interval is long in production) still unblocks."""

    async def main():
        store = configure_store(":memory:")
        runner = Runner(store=store, liveness_interval=0.1)
        pk = store.create_process_node(NodeType.PROCESS, "Ghost")

        async def terminate_silently():
            await asyncio.sleep(0.25)
            store.update_process(pk, state="finished")

        asyncio.ensure_future(terminate_silently())
        await asyncio.wait_for(runner.wait_for_process(pk), 5)

    run(main())


def test_wait_on_already_terminal_process_returns_immediately():
    async def main():
        store = configure_store(":memory:")
        runner = Runner(store=store)
        pk = store.create_process_node(NodeType.PROCESS, "Done")
        store.update_process(pk, state="finished")
        t0 = time.monotonic()
        await runner.wait_for_process(pk)
        assert time.monotonic() - t0 < 0.5

    run(main())


# ---------------------------------------------------------------------------
# durable kill: survives worker restarts, no resurrection
# ---------------------------------------------------------------------------

def test_kill_is_durable_across_worker_restart(tmp_path):
    db = str(tmp_path / "store.db")

    async def main():
        store = configure_store(db)
        runner1 = Runner(store=store)
        process = Spin(inputs={}, runner=runner1)
        pk = process.pk
        assert store.load_checkpoint(pk) is not None

        # the control plane records the kill while no worker runs the pk
        # (worker died mid-flight); only the durable marker remains
        process._control_handler({"intent": "kill", "message": "op kill"})

        # a restarted worker picks the task back up from the queue …
        runner2 = Runner(store=store)
        handler = make_process_task_handler(runner2, store)
        await handler({"pk": pk})

        # … and honours the kill instead of resurrecting the process
        node = store.get_node(pk)
        assert node["process_state"] == "killed"
        assert node["exit_status"] == 998
        assert store.load_checkpoint(pk) is None

        # duplicate redelivery after termination: a no-op, not an error
        await handler({"pk": pk})
        assert store.get_node(pk)["process_state"] == "killed"

    run(main())


def test_worker_handler_tracks_owned_pks(tmp_path):
    async def main():
        store = configure_store(":memory:")
        runner = Runner(store=store)
        process = Quick(inputs={}, runner=runner)
        owned: set = set()
        handler = make_process_task_handler(runner, store, owned)
        task = asyncio.ensure_future(handler({"pk": process.pk}))
        await asyncio.sleep(0.02)
        assert owned == {process.pk}
        await task
        assert owned == set()

    run(main())


def test_slot_queued_process_is_controllable():
    """A submitted process waiting for a slot already has its control
    endpoint: kill reaches it before it ever starts stepping."""

    async def main():
        store = configure_store(":memory:")
        runner = Runner(store=store, slots=1)
        blocker = runner.submit(Spin, {})
        queued = runner.submit(Spin, {})
        await asyncio.sleep(0.05)
        # both controllable; the queued one holds no slot yet
        runner.control(queued.pk, "kill", message="never ran")
        runner.control(blocker.pk, "kill", message="done blocking")
        await asyncio.wait_for(queued.process.wait_done(), 10)
        await asyncio.wait_for(blocker.process.wait_done(), 10)
        assert store.get_node(queued.pk)["process_state"] == "killed"

    run(main())


def test_cli_kill_falls_back_to_durable_marker(tmp_path, capsys):
    """`repro process kill` on a pk with no live endpoint (queued, or its
    worker died) records the kill durably; the next pickup honours it."""
    from repro import cli

    db = str(tmp_path / "store.db")

    async def main():
        server = BrokerServer(str(tmp_path / "broker.db"))
        host, port = await server.start()
        with open(tmp_path / "broker.json", "w") as fh:
            json.dump({"host": host, "port": port}, fh)
        store = configure_store(db)
        process = Spin(inputs={}, runner=Runner(store=store))
        pk = process.pk
        store.close()

        def cli_kill():
            cli.main(["-p", db, "process", "kill", str(pk),
                      "-w", str(tmp_path), "--message", "late kill"])

        await asyncio.get_running_loop().run_in_executor(None, cli_kill)
        return pk

    pk = run(main())
    assert "kill recorded durably" in capsys.readouterr().out

    async def resume():
        store = configure_store(db)
        runner = Runner(store=store)
        await make_process_task_handler(runner, store)({"pk": pk})
        return store.get_node(pk)

    node = run(resume())
    assert node["process_state"] == "killed"
    assert json.loads(node["attributes"])["kill_requested"] == "late kill"


# ---------------------------------------------------------------------------
# durable broadcast log + replay
# ---------------------------------------------------------------------------

def test_event_log_replays_missed_broadcasts(tmp_path):
    async def main():
        server, worker, _ = await _broker_pair(tmp_path)
        for state in ("running", "finished"):
            worker.broadcast_send(state_subject(9, state), sender=9,
                                  body={"pk": 9, "state": state})
        await asyncio.sleep(0.1)    # let the broker log them

        def sync_part():
            # a watcher connecting AFTER the fact still sees the events
            client = SyncBrokerClient(server.host, server.port)
            try:
                events = list(client.events(
                    subject_filter="state_changed.9.*", timeout=1.0,
                    replay_since=0))
            finally:
                client.close()
            return events

        events = await asyncio.get_running_loop().run_in_executor(
            None, sync_part)
        states = [body["state"] for _, _, body in events]
        assert states == ["running", "finished"]

    run(main())


# ---------------------------------------------------------------------------
# the full stack: daemon worker + broker + CLI kill (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_kill_terminates_daemon_process(tmp_path):
    from repro import cli
    from repro.calcjobs import TPUTrainJob
    from repro.core import Dict as DictData
    from repro.engine.controller import ProcessController
    from repro.engine.daemon import Daemon

    daemon = Daemon(str(tmp_path), workers=1, slots=4)
    daemon.start()
    try:
        # a job long enough that it is still running when the kill lands
        pk = daemon.submit(TPUTrainJob, {"config": DictData(
            {"arch": "qwen2-0.5b", "steps": 5000, "batch": 1, "seq": 8})})
        store = configure_store(daemon.store_path)

        t0 = time.time()
        while time.time() - t0 < 120:
            node = store.get_node(pk) or {}
            if node.get("process_state") in ("running", "waiting"):
                break
            daemon.supervise()
            time.sleep(0.3)
        else:
            pytest.fail(f"process never started: {node}")

        cli.main(["-p", daemon.store_path, "process", "kill", str(pk),
                  "-w", str(tmp_path), "--message", "cli kill"])

        t0 = time.time()
        while time.time() - t0 < 60:
            node = store.get_node(pk)
            if node["process_state"] in TERMINAL:
                break
            time.sleep(0.2)
        assert node["process_state"] == "killed", node
        assert node["exit_status"] == 998
        assert json.loads(node["attributes"])["kill_requested"] == "cli kill"

        # the durable event log lets a late watcher see the whole story
        with ProcessController.from_workdir(str(tmp_path)) as ctl:
            events = list(ctl.watch(pk=pk, timeout=2.0, replay_since=0))
        assert any(body.get("state") == "killed"
                   for _, _, body in events), events
    finally:
        daemon.stop()


@pytest.mark.slow
def test_daemon_wait_latency_under_poll_floor(tmp_path):
    """Runner.wait on a daemon-run process completes via broadcast well
    under the old 2 s poll interval after the terminal transition."""
    from repro.calcjobs import TPUTrainJob
    from repro.core import Dict as DictData
    from repro.engine.daemon import Daemon

    daemon = Daemon(str(tmp_path), workers=1, slots=4)
    daemon.start()
    try:
        pk = daemon.submit(TPUTrainJob, {"config": DictData(
            {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8})})
        store = configure_store(daemon.store_path)

        async def main():
            client = BrokerClient(daemon.host, daemon.port)
            await client.connect()
            terminal_seen_at = {}

            def stamp(subject, sender, body):
                parsed = parse_state_subject(subject)
                if parsed and parsed[1] in TERMINAL:
                    terminal_seen_at[parsed[0]] = time.monotonic()

            client.add_broadcast_subscriber(stamp, f"state_changed.{pk}.*")
            runner = Runner(store=store, communicator=client)
            node = await asyncio.wait_for(runner.wait(pk), 300)
            waited_until = time.monotonic()
            client.close()
            return node, terminal_seen_at.get(pk), waited_until

        node, seen_at, waited_until = run(main(), timeout=320)
        assert node["process_state"] == "finished"
        assert seen_at is not None, "terminal broadcast never arrived"
        # the wait unblocked promptly after the broadcast — not after a
        # poll interval tick
        assert waited_until - seen_at < 1.0
    finally:
        daemon.stop()
