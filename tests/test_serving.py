"""Continuous-batching scheduler, prefill/decode parity, sharded-serving
equivalence, and the provenance-cached generate() workload.

Everything runs on CPU: the Pallas decode path executes in interpret mode,
and the multi-device test forces fake host devices in a subprocess (the
main pytest process keeps its single CPU device).
"""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.registry import build
from repro.serving.serve import (BatchScheduler, Request, make_decode_step,
                                 make_prefill_step)

ARCH = "aiida-demo-110m"
RNG = np.random.default_rng(7)


def _build(decode_impl="direct", **over):
    cfg = reduced_config(ARCH).replace(
        dtype="float32", kv_cache_dtype="float32",
        decode_impl=decode_impl, **over)
    bundle = build(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def lm():
    return _build()


def _prompts(n, length=6):
    return [RNG.integers(1, 500, length).tolist() for _ in range(n)]


def _serve(bundle, params, prompts, new_tokens, *, batch=2, max_len=64,
           eos_id=-1):
    sched = BatchScheduler(bundle, params, batch_size=batch,
                           max_len=max_len, eos_id=eos_id)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=new_tokens)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.run()
    return sched, reqs


# ---------------------------------------------------------------------------
# BatchScheduler unit tests
# ---------------------------------------------------------------------------

def test_max_new_tokens_enforced(lm):
    _, reqs = _serve(*lm, _prompts(3), new_tokens=5)
    for r in reqs:
        assert r.done and r.finish_reason == "length"
        assert len(r.generated) == 5


def test_slot_reuse_after_eos(lm):
    prompts = _prompts(2)
    # discover what the model actually says, then make token #2 the EOS
    _, probe = _serve(*lm, [prompts[0]], new_tokens=4)
    eos = probe[0].generated[1]
    sched, reqs = _serve(*lm, prompts, new_tokens=8, batch=1, eos_id=eos)
    assert reqs[0].finish_reason == "eos"
    assert len(reqs[0].generated) <= 2
    assert reqs[1].done                       # queued request got the slot
    assert reqs[1].started_at >= reqs[0].finished_at
    assert all(s is None for s in sched.slots)


def test_fifo_admission_under_full_batch(lm):
    _, reqs = _serve(*lm, _prompts(6), new_tokens=4, batch=2)
    starts = [r.started_at for r in reqs]
    assert starts == sorted(starts), \
        "admission must follow submission order (FIFO)"
    assert all(r.done for r in reqs)


def test_determinism_under_fixed_seed(lm):
    prompts = _prompts(4)
    _, a = _serve(*lm, prompts, new_tokens=6, batch=2)
    _, b = _serve(*lm, prompts, new_tokens=6, batch=2)
    assert [r.generated for r in a] == [r.generated for r in b]


def test_cobatched_neighbors_do_not_leak(lm):
    """A request's tokens must not depend on what shares its micro-batch."""
    prompts = _prompts(4)
    _, alone = _serve(*lm, [prompts[0]], new_tokens=6, batch=4)
    _, crowd = _serve(*lm, prompts, new_tokens=6, batch=4)
    assert alone[0].generated == crowd[0].generated


def test_cache_full_eviction(lm):
    bundle, params = lm
    sched = BatchScheduler(bundle, params, batch_size=1, max_len=16)
    req = Request(rid=0, prompt=_prompts(1, length=12)[0],
                  max_new_tokens=100)
    sched.submit(req)
    sched.run()
    assert req.done and req.finish_reason == "cache_full"
    assert len(req.generated) < 100


def test_oversized_prompt_rejected(lm):
    bundle, params = lm
    sched = BatchScheduler(bundle, params, batch_size=1, max_len=16)
    with pytest.raises(ValueError, match="cannot fit"):
        sched.submit(Request(rid=0, prompt=list(range(1, 17)),
                             max_new_tokens=1))


def test_max_pending_rejects_with_counter(lm):
    from repro.observability.metrics import get_registry
    from repro.serving.serve import QueueFullError

    bundle, params = lm
    sched = BatchScheduler(bundle, params, batch_size=1, max_len=16,
                           max_pending=2)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new_tokens=1)
            for i in range(3)]
    sched.submit(reqs[0])
    sched.submit(reqs[1])
    rejected = get_registry().counter("serving.rejected")
    before = rejected.value
    with pytest.raises(QueueFullError, match="max_pending=2"):
        sched.submit(reqs[2])
    assert rejected.value == before + 1
    # the bound is backpressure, not a death sentence: once the queue
    # drains the same request is admissible again
    sched.run()
    assert reqs[0].done and reqs[1].done
    sched.submit(reqs[2])
    assert len(sched.queue) == 1


def test_max_pending_validation(lm):
    bundle, params = lm
    with pytest.raises(ValueError, match="max_pending"):
        BatchScheduler(bundle, params, batch_size=1, max_len=16,
                       max_pending=0)


def test_recurrent_family_rejected():
    cfg = reduced_config("recurrentgemma-2b")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent"):
        BatchScheduler(bundle, params, batch_size=1, max_len=16)


def test_pallas_decode_matches_direct(lm):
    """The flash-decode kernel routing is numerically interchangeable with
    the masked-einsum path at serving time (greedy tokens identical)."""
    prompts = _prompts(3)
    _, direct = _serve(*lm, prompts, new_tokens=6, batch=2)
    pallas = _build(decode_impl="pallas")
    _, routed = _serve(*pallas, prompts, new_tokens=6, batch=2)
    assert [r.generated for r in direct] == [r.generated for r in routed]


# ---------------------------------------------------------------------------
# prefill/decode parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["aiida-demo-110m", "recurrentgemma-2b"])
def test_prefill_equals_stepwise_decode(arch):
    """Prefilling N tokens must land in the same state as feeding those N
    tokens one decode step at a time: identical next token and identical
    greedy continuation."""
    cfg = reduced_config(arch).replace(dtype="float32",
                                       kv_cache_dtype="float32")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(1))
    prefill = jax.jit(make_prefill_step(bundle))
    decode = jax.jit(make_decode_step(bundle))
    n, extra, max_len = 8, 4, 32
    prompt = jnp.asarray(RNG.integers(1, cfg.vocab_size, (1, n)), jnp.int32)

    def continue_greedy(tok, cache, pos):
        seq = [int(np.asarray(tok)[0, 0])]
        for i in range(extra):
            tok, cache = decode(params, cache, tok,
                                jnp.asarray(pos + i, jnp.int32))
            seq.append(int(np.asarray(tok)[0, 0]))
        return seq

    tok_a, cache_a = prefill(params, {"tokens": prompt},
                             bundle.init_cache(1, max_len))
    seq_a = continue_greedy(tok_a, cache_a, n)

    tok_b, cache_b = prefill(params, {"tokens": prompt[:, :1]},
                             bundle.init_cache(1, max_len))
    for i in range(1, n):
        tok_b, cache_b = decode(params, cache_b, prompt[:, i:i + 1],
                                jnp.asarray(i, jnp.int32))
    seq_b = continue_greedy(tok_b, cache_b, n)
    assert seq_a == seq_b


# ---------------------------------------------------------------------------
# sharded serving equivalence (fake multi-device CPU, subprocess)
# ---------------------------------------------------------------------------

SHARDED_SERVE_PROG = textwrap.dedent("""
    import sys
    sys.path.insert(0, {src!r})
    from repro.configs import make_serving_mesh, reduced_config, setup_devices
    devs = setup_devices(platform="cpu", n_devices=2)
    assert len(devs) == 2, devs
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.sharding import make_rules
    from repro.models.common import axis_rules
    from repro.models.registry import build
    from repro.serving.serve import make_decode_step, make_prefill_step

    cfg = reduced_config("aiida-demo-110m").replace(
        dtype="float32", kv_cache_dtype="float32", decode_impl="pallas")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8)), jnp.int32)

    def run(mesh_rules):
        prefill = jax.jit(make_prefill_step(bundle))
        decode = jax.jit(make_decode_step(bundle))

        def body():
            cache = bundle.init_cache(2, 32)
            tok, cache = prefill(params, {{"tokens": prompt}}, cache)
            toks = [np.asarray(tok)]
            pos = np.array([8, 8], np.int32)
            for _ in range(4):
                tok, cache = decode(params, cache, tok,
                                    jnp.asarray(pos, jnp.int32))
                toks.append(np.asarray(tok))
                pos += 1
            return np.concatenate(toks, axis=1)

        if mesh_rules is None:
            return body()
        with axis_rules(*mesh_rules):
            return body()

    single = run(None)
    mesh = make_serving_mesh(data=1, model=2)
    rules = make_rules(cfg, mesh, fsdp=False)
    sharded = run((mesh, rules))
    print("RESULT:" + json.dumps({{
        "ok": bool((single == sharded).all()),
        "single": single.tolist(), "sharded": sharded.tolist(),
        "heads_rule": str(rules["heads"]),
    }}))
""")


def test_sharded_decode_matches_single_device():
    import os
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prog = SHARDED_SERVE_PROG.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", prog],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    result = json.loads(line[0][len("RESULT:"):])
    assert result["heads_rule"] == "model"    # heads really were sharded
    assert result["ok"], result


# ---------------------------------------------------------------------------
# provenance-cached generation workload
# ---------------------------------------------------------------------------

def test_generate_cache_hit_runs_zero_decode_steps(runner):
    from repro.caching import enable_caching
    from repro.core.datatypes import ArrayData, Int, Str
    from repro.observability.metrics import get_registry
    from repro.serving.inference import (generate, prompt_fingerprint,
                                         reset_engines)

    reset_engines()
    steps = get_registry().counter("serving.decode_steps")
    prompt = [3, 5, 7, 11, 13]

    def call():
        return generate(Str(ARCH), ArrayData(np.asarray(prompt, np.int32)),
                        Int(4), Int(0), Int(-1))

    with enable_caching():
        cold = call()
        before = steps.value
        hot = call()
    assert steps.value == before, "cache hit must not touch the decoder"
    np.testing.assert_array_equal(np.asarray(cold["tokens"].value),
                                  np.asarray(hot["tokens"].value))
    stats = hot["stats"].value
    assert stats["new_tokens"] == len(np.asarray(hot["tokens"].value))
    assert stats["fingerprint"] == prompt_fingerprint(ARCH, 0, prompt)


def test_generate_distinct_prompts_do_not_collide(runner):
    from repro.caching import enable_caching
    from repro.core.datatypes import ArrayData, Int, Str
    from repro.serving.inference import generate, reset_engines

    reset_engines()
    with enable_caching():
        a = generate(Str(ARCH), ArrayData(np.asarray([1, 2, 3], np.int32)),
                     Int(4), Int(0), Int(-1))
        b = generate(Str(ARCH), ArrayData(np.asarray([1, 2, 4], np.int32)),
                     Int(4), Int(0), Int(-1))
    fa = a["stats"].value["fingerprint"]
    fb = b["stats"].value["fingerprint"]
    assert fa != fb


def test_engine_memo_buckets_by_cache_size():
    from repro.serving.inference import get_engine, reset_engines

    reset_engines()
    e1 = get_engine(ARCH, 0, need_len=10)
    e2 = get_engine(ARCH, 0, need_len=100)     # same 128-slot bucket
    e3 = get_engine(ARCH, 0, need_len=200)     # next power of two
    assert e1 is e2
    assert e3 is not e1
    assert e3.scheduler.max_len == 256
