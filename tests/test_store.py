"""Provenance hot-path overhaul (ISSUE 5): blob repository, write
batching / unit-of-work, bulk read+write APIs, legacy-profile migration
and multi-OS-process concurrency."""

import json
import os
import sqlite3
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.datatypes import ArrayData, FolderData, Int
from repro.provenance.repository import BlobNotFound, BlobRepository
from repro.provenance.store import (
    SUMMARY_COLUMNS, LinkType, NodeType, ProvenanceStore, QueryBuilder,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# BlobRepository
# ---------------------------------------------------------------------------

class TestBlobRepository:
    def test_put_get_roundtrip(self, tmp_path):
        repo = BlobRepository(str(tmp_path / "repo"))
        digest = repo.put(b"hello world")
        assert repo.get(digest) == b"hello world"
        assert repo.has(digest)
        assert not repo.has("0" * 64)

    def test_content_addressing_dedups(self, tmp_path):
        repo = BlobRepository(str(tmp_path / "repo"))
        d1 = repo.put(b"same bytes")
        d2 = repo.put(b"same bytes")
        assert d1 == d2
        assert list(repo.digests()) == [d1]
        assert repo.stats() == {"blobs": 1, "bytes": len(b"same bytes")}

    def test_missing_blob_raises(self, tmp_path):
        repo = BlobRepository(str(tmp_path / "repo"))
        with pytest.raises(BlobNotFound):
            repo.get("ab" * 32)

    def test_in_memory_repo(self):
        repo = BlobRepository(None)
        d = repo.put(b"x" * 100)
        assert repo.get(d) == b"x" * 100
        assert repo.stats()["blobs"] == 1


# ---------------------------------------------------------------------------
# payload routing through the repository
# ---------------------------------------------------------------------------

class TestPayloadRouting:
    def test_small_array_stays_inline(self, tmp_path):
        st = ProvenanceStore(str(tmp_path / "p.db"), inline_threshold=4096)
        v = st.store_data(ArrayData(np.arange(8)))
        row = st.get_node(v.pk)
        assert "npy_b64" in json.loads(row["payload"])
        assert st.repository.stats()["blobs"] == 0
        assert np.array_equal(st.load_data(v.pk).value, np.arange(8))

    def test_large_array_goes_to_blob(self, tmp_path):
        st = ProvenanceStore(str(tmp_path / "p.db"), inline_threshold=256)
        arr = np.arange(1024, dtype=np.float64)
        v = st.store_data(ArrayData(arr))
        doc = json.loads(st.get_node(v.pk)["payload"])
        assert set(doc) == {"type", "blob"}
        assert st.repository.has(doc["blob"])
        # transparent rehydration
        assert np.array_equal(st.load_data(v.pk).value, arr)

    def test_equal_arrays_share_one_blob(self, tmp_path):
        st = ProvenanceStore(str(tmp_path / "p.db"), inline_threshold=256)
        arr = np.arange(1024, dtype=np.float64)
        a = st.store_data(ArrayData(arr))
        b = st.store_data(ArrayData(arr.copy()))
        assert a.pk != b.pk
        docs = [json.loads(st.get_node(pk)["payload"])
                for pk in (a.pk, b.pk)]
        assert docs[0]["blob"] == docs[1]["blob"]
        assert st.repository.stats()["blobs"] == 1

    def test_folder_mixed_inline_and_blob(self, tmp_path):
        st = ProvenanceStore(str(tmp_path / "p.db"), inline_threshold=64)
        files = {"small.txt": b"tiny", "big.bin": os.urandom(500)}
        v = st.store_data(FolderData(files))
        doc = json.loads(st.get_node(v.pk)["payload"])
        assert "small.txt" in doc["files"]
        assert "big.bin" in doc["blobs"]
        loaded = st.load_data(v.pk)
        assert loaded.get_bytes("small.txt") == b"tiny"
        assert loaded.get_bytes("big.bin") == files["big.bin"]

    def test_threshold_env_var(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_REPO_INLINE_MAX", "128")
        st = ProvenanceStore(str(tmp_path / "p.db"))
        assert st.inline_threshold == 128


# ---------------------------------------------------------------------------
# bulk write APIs
# ---------------------------------------------------------------------------

class TestBulkWrites:
    def test_store_data_many_assigns_pks(self, store):
        values = [Int(i) for i in range(10)]
        store.store_data_many(values)
        assert all(v.is_stored for v in values)
        assert len({v.pk for v in values}) == 10
        assert store.load_data(values[3].pk).value == 3

    def test_store_data_many_skips_stored_and_duplicates(self, store):
        a = store.store_data(Int(1))
        b = Int(2)
        before = store.count_nodes()
        store.store_data_many([a, b, b])   # stored + same object twice
        assert store.count_nodes() == before + 1
        assert b.is_stored

    def test_add_links_and_links_for(self, store):
        p = store.create_process_node(NodeType.CALC_FUNCTION, "F")
        vals = store.store_data_many([Int(i) for i in range(4)])
        store.add_links([(v.pk, p, LinkType.INPUT_CALC, f"x{i}")
                         for i, v in enumerate(vals)])
        links = store.links_for([p])
        assert len(links) == 4
        assert {l[3] for l in links} == {"x0", "x1", "x2", "x3"}
        # direction filters
        assert store.links_for([p], direction="in") == links
        assert store.links_for([p], direction="out") == []
        # each link appears once even when both endpoints are selected
        both = store.links_for([p, vals[0].pk])
        assert len(both) == 4

    def test_add_logs_bulk_and_logs_for(self, store):
        p1 = store.create_process_node(NodeType.WORK_CHAIN, "W1")
        p2 = store.create_process_node(NodeType.WORK_CHAIN, "W2")
        store.add_logs([(p1, "REPORT", "first", 1.0),
                        (p2, "REPORT", "other", 2.0),
                        (p1, "REPORT", "second", 3.0)])
        by_node = store.logs_for([p1, p2])
        assert [e["message"] for e in by_node[p1]] == ["first", "second"]
        assert by_node[p2][0]["message"] == "other"
        assert store.get_logs(p1)[0]["message"] == "first"

    def test_insert_node_rows_bulk(self, store):
        records = [{"uuid": f"u-{i}", "node_type": "data",
                    "payload": {"type": "int", "value": i},
                    "ctime": 1.0, "mtime": 1.0} for i in range(5)]
        pks = store.insert_node_rows(records)
        assert len(pks) == 5
        assert store.load_data(pks[2]).value == 2
        assert store.get_node_by_uuid("u-4")["pk"] == pks[4]

    def test_transaction_batches_commits(self, store):
        c0 = store.stats["commits"]
        with store.transaction():
            store.store_data(Int(1))
            store.store_data(Int(2))
            p = store.create_process_node(NodeType.CALC_FUNCTION, "F")
            store.add_log(p, "REPORT", "hi")
        assert store.stats["commits"] == c0 + 1


# ---------------------------------------------------------------------------
# transaction hooks: rollback identity cleanup, post-commit ordering
# ---------------------------------------------------------------------------

class TestTransactionHooks:
    def test_rollback_unassigns_bulk_pks(self, store):
        v = Int(5)
        with pytest.raises(RuntimeError):
            with store.transaction():
                store.store_data_many([v])
                assert v.is_stored
                raise RuntimeError("boom")
        # the row was rolled back, so the value must not keep its pk —
        # otherwise a later store would skip it and links would dangle
        assert not v.is_stored and v.pk is None and v.uuid is None
        store.store_data(v)
        assert store.load_data(v.pk).value == 5

    def test_rollback_unassigns_single_pk(self, store):
        v = Int(7)
        with pytest.raises(RuntimeError):
            with store.transaction():
                store.store_data(v)
                raise RuntimeError("boom")
        assert v.pk is None and v.uuid is None

    def test_after_commit_defers_until_commit(self, store):
        fired = []
        with store.transaction():
            store.after_commit(lambda: fired.append(store.count_nodes()))
            store.store_data(Int(1))
            assert fired == []          # not yet: txn still open
        assert fired == [1]             # ran post-commit, sees the row

    def test_after_commit_immediate_outside_txn(self, store):
        fired = []
        store.after_commit(lambda: fired.append(1))
        assert fired == [1]

    def test_after_commit_dropped_on_rollback(self, store):
        fired = []
        with pytest.raises(RuntimeError):
            with store.transaction():
                store.after_commit(lambda: fired.append(1))
                raise RuntimeError("boom")
        assert fired == []

    def test_terminal_broadcast_after_durable_write(self, tmp_path):
        """The state_changed terminal broadcast must not beat the commit:
        an observer in another OS process reads the store the moment the
        broadcast lands and must see the final state and output links."""
        from repro.core import calcfunction
        from repro.engine.runner import Runner, set_default_runner

        @calcfunction
        def add(a, b):
            return a + b

        db = str(tmp_path / "p.db")
        st = ProvenanceStore(db)
        runner = Runner(store=st)
        set_default_runner(runner)
        observed = []
        orig = runner.communicator.broadcast_send

        def spy(subject=None, sender=None, body=None, **kw):
            if body and body.get("state") == "finished":
                # a fresh connection sees only *committed* state, exactly
                # like a waiter in another OS process would
                conn = sqlite3.connect(db)
                try:
                    row = conn.execute(
                        "SELECT process_state FROM nodes WHERE pk=?",
                        (body["pk"],)).fetchone()
                    n_out = conn.execute(
                        "SELECT COUNT(*) FROM links WHERE in_id=?"
                        " AND link_type='create'",
                        (body["pk"],)).fetchone()[0]
                    observed.append((row[0] if row else None, n_out))
                finally:
                    conn.close()
            return orig(subject=subject, sender=sender, body=body, **kw)

        runner.communicator.broadcast_send = spy
        try:
            add(Int(1), Int(2))
        finally:
            set_default_runner(None)
            st.close()
        assert observed == [("finished", 1)]


# ---------------------------------------------------------------------------
# bulk/projected reads
# ---------------------------------------------------------------------------

class TestBulkReads:
    def test_get_nodes_batched(self, store):
        vals = store.store_data_many([Int(i) for i in range(7)])
        rows = store.get_nodes([v.pk for v in vals] + [99999])
        assert set(rows) == {v.pk for v in vals}   # missing pk absent

    def test_get_nodes_projection_adds_pk(self, store):
        v = store.store_data(Int(5))
        rows = store.get_nodes([v.pk], columns=("uuid",))
        assert set(rows[v.pk]) == {"pk", "uuid"}

    def test_get_node_projection(self, store):
        p = store.create_process_node(NodeType.CALC_FUNCTION, "F")
        row = store.get_node(p, columns=SUMMARY_COLUMNS)
        assert "payload" not in row and "checkpoint" not in row
        assert row["process_type"] == "F"

    def test_unknown_column_rejected(self, store):
        with pytest.raises(ValueError):
            store.get_node(1, columns=("pk", "evil; DROP TABLE nodes"))

    def test_unfinished_excludes_bulk_text(self, store):
        store.create_process_node(NodeType.CALC_FUNCTION, "F")
        rows = store.unfinished_processes()
        assert rows and "payload" not in rows[0]


# ---------------------------------------------------------------------------
# QueryBuilder satellites
# ---------------------------------------------------------------------------

class TestQueryBuilderFixes:
    def _fill(self, store, n=5):
        for i in range(n):
            store.create_process_node(NodeType.CALC_FUNCTION, f"T{i}")

    def test_limit_zero_returns_no_rows(self, store):
        self._fill(store)
        assert QueryBuilder(store).limit(0).all() == []

    def test_first_does_not_clobber_limit(self, store):
        self._fill(store)
        qb = QueryBuilder(store).limit(3)
        first = qb.first()
        assert first["process_type"] == "T0"
        assert len(qb.all()) == 3   # limit(3) survived first()

    def test_first_without_limit(self, store):
        self._fill(store)
        qb = QueryBuilder(store)
        assert qb.first()["process_type"] == "T0"
        assert len(qb.all()) == 5   # still unlimited

    def test_project(self, store):
        self._fill(store, 2)
        rows = QueryBuilder(store).project("process_type").all()
        assert set(rows[0]) == {"pk", "process_type"}


# ---------------------------------------------------------------------------
# schema migration: legacy profile (inline payloads, no logs index)
# ---------------------------------------------------------------------------

def _legacy_profile(path: str, arr: np.ndarray) -> None:
    """Build a pre-overhaul profile with raw SQL: inline base64 array
    payload, no logs index, no repo, no meta stamp."""
    import base64
    import io

    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    payload = json.dumps({"type": "array",
                          "npy_b64": base64.b64encode(
                              buf.getvalue()).decode()})
    conn = sqlite3.connect(path)
    conn.executescript("""
    CREATE TABLE nodes (
        pk INTEGER PRIMARY KEY AUTOINCREMENT, uuid TEXT UNIQUE NOT NULL,
        node_type TEXT NOT NULL, process_type TEXT, label TEXT DEFAULT '',
        description TEXT DEFAULT '', attributes TEXT DEFAULT '{}',
        payload TEXT, process_state TEXT, exit_status INTEGER,
        exit_message TEXT, checkpoint TEXT, node_hash TEXT,
        ctime REAL NOT NULL, mtime REAL NOT NULL);
    CREATE TABLE links (
        pk INTEGER PRIMARY KEY AUTOINCREMENT, in_id INTEGER NOT NULL,
        out_id INTEGER NOT NULL, link_type TEXT NOT NULL,
        label TEXT NOT NULL);
    CREATE TABLE logs (
        pk INTEGER PRIMARY KEY AUTOINCREMENT, node_id INTEGER NOT NULL,
        levelname TEXT NOT NULL, message TEXT NOT NULL, time REAL NOT NULL);
    CREATE TABLE meta (key TEXT PRIMARY KEY, value TEXT);
    """)
    conn.execute(
        "INSERT INTO nodes (uuid, node_type, payload, ctime, mtime)"
        " VALUES ('data-u1', 'data', ?, 1.0, 1.0)", (payload,))
    conn.execute(
        "INSERT INTO nodes (uuid, node_type, process_type, process_state,"
        " exit_status, node_hash, ctime, mtime) VALUES ('proc-u1',"
        " 'process.calcfunction', 'legacy_fn', 'finished', 0, 'hash-1',"
        " 2.0, 2.0)")
    conn.execute("INSERT INTO links (in_id, out_id, link_type, label)"
                 " VALUES (2, 1, 'create', 'result')")
    conn.execute("INSERT INTO logs (node_id, levelname, message, time)"
                 " VALUES (2, 'REPORT', 'legacy log', 2.0)")
    conn.commit()
    conn.close()


class TestLegacyMigration:
    def test_legacy_profile_migrates_on_open(self, tmp_path):
        db = str(tmp_path / "legacy.db")
        arr = np.arange(2048, dtype=np.float64)
        _legacy_profile(db, arr)

        st = ProvenanceStore(db, inline_threshold=1024)
        # payload moved out of the nodes table into the repository
        doc = json.loads(st.get_node(1)["payload"])
        assert "blob" in doc and st.repository.has(doc["blob"])
        # content identical after the move
        assert np.array_equal(st.load_data(1).value, arr)
        # logs index created
        idx = {r["name"] for r in st._conn().execute(
            "PRAGMA index_list(logs)")}
        assert "idx_logs_node" in idx
        # graph untouched
        assert st.get_logs(2) == [
            {"levelname": "REPORT", "message": "legacy log", "time": 2.0}]
        assert st.outgoing(2) == [(1, "create", "result")]

    def test_migration_is_one_shot(self, tmp_path):
        db = str(tmp_path / "legacy.db")
        _legacy_profile(db, np.arange(2048, dtype=np.float64))
        st = ProvenanceStore(db, inline_threshold=1024)
        assert st.get_meta("repo_version") == "1"
        st.close()
        # reopening does not re-scan (stamp present) and changes nothing
        st2 = ProvenanceStore(db, inline_threshold=1024)
        assert "blob" in json.loads(st2.get_node(1)["payload"])

    def test_legacy_cache_hits_unchanged_after_migration(self, tmp_path):
        """The acceptance flow: a profile written with inline payloads
        keeps serving cache hits after the payloads move to blobs."""
        from repro.caching.config import enable_caching
        from repro.engine.runner import Runner, set_default_runner

        db = str(tmp_path / "prof.db")
        code_common = """
from repro.core import calcfunction, ArrayData
import numpy as np

@calcfunction
def make_big(seed):
    rng = np.random.default_rng(int(seed))
    return ArrayData(rng.normal(size=2048))
"""
        ns: dict = {}
        exec(code_common, ns)
        make_big = ns["make_big"]

        # 'legacy' era: huge threshold => payloads inline, like the seed
        st = ProvenanceStore(db, inline_threshold=10**9)
        set_default_runner(Runner(store=st))
        cold = make_big(Int(7))
        cold_pk = cold.pk
        st.close()
        set_default_runner(None)
        # strip the migration stamp: a real legacy profile has none
        conn = sqlite3.connect(db)
        conn.execute("DELETE FROM meta WHERE key='repo_version'")
        conn.commit()
        conn.close()

        # reopen with the real threshold: migration moves the payload out
        st2 = ProvenanceStore(db, inline_threshold=4096)
        assert "blob" in json.loads(st2.get_node(cold_pk)["payload"])
        set_default_runner(Runner(store=st2))
        with enable_caching():
            warm = make_big(Int(7))
        node = st2.get_node(warm.pk if hasattr(warm, "pk") else cold_pk)
        # the creating process of `warm` must be a cache clone
        creators = st2.incoming(warm.pk, LinkType.CREATE)
        attrs = json.loads(
            st2.get_node(creators[0][0])["attributes"] or "{}")
        assert "cached_from" in attrs
        assert np.array_equal(warm.value, cold.value)
        set_default_runner(None)
        st2.close()
        assert node is not None


# ---------------------------------------------------------------------------
# engine unit of work: commits per process
# ---------------------------------------------------------------------------

class TestUnitOfWork:
    def test_calcfunction_costs_two_commits(self, tmp_path):
        from repro.core import calcfunction
        from repro.engine.runner import Runner, set_default_runner

        @calcfunction
        def add(a, b):
            return a + b

        st = ProvenanceStore(str(tmp_path / "p.db"))
        set_default_runner(Runner(store=st))
        try:
            add(Int(1), Int(2))     # warm spec/import caches
            c0 = st.stats["commits"]
            add(Int(3), Int(4))
            per_process = st.stats["commits"] - c0
            # creation txn + terminal txn; allow 3 for safety margin
            assert per_process <= 3, per_process
        finally:
            set_default_runner(None)
            st.close()

    def test_checkpoint_dirty_skip(self, store, runner):
        """An unchanged checkpoint is not rewritten (dirty-flag check)."""
        from repro.core import Int as _Int
        from repro.core import WorkChain

        class Chain(WorkChain):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("n", valid_type=_Int, default=_Int(0))
                spec.output("r", valid_type=_Int)
                spec.outline(cls.go)

            def go(self):
                self.out("r", _Int(1))

        h = runner.submit(Chain, {"n": _Int(1)})
        runner.loop.run_until_complete(h.process.wait_done())
        assert h.process.exit_code.status == 0
        # terminal: checkpoint removed, one row, outputs linked
        assert store.load_checkpoint(h.pk) is None


# ---------------------------------------------------------------------------
# checkpoints reference stored payloads instead of embedding them
# ---------------------------------------------------------------------------

class TestCheckpointByReference:
    def test_checkpoint_has_no_payload_copy(self, store, runner):
        from repro.calcjobs import TPUTrainJob  # noqa: F401 — import check
        from repro.core import WorkChain

        class Hold(WorkChain):
            @classmethod
            def define(cls, spec):
                super().define(spec)
                spec.input("arr", valid_type=ArrayData)
                spec.outline(cls.go)

            def go(self):
                pass

        arr = np.arange(4096, dtype=np.float64)
        proc = Hold({"arr": ArrayData(arr)}, runner=runner)
        ckpt = store.load_checkpoint(proc.pk)
        entry = ckpt["inputs"]["arr"]
        assert "__data_ref__" in entry          # reference, not a copy
        assert "npy_b64" not in json.dumps(ckpt)
        # recreation rehydrates the reference through the store
        from repro.core.process import _deserialize_inputs
        vals = _deserialize_inputs(ckpt["inputs"], store)
        assert np.array_equal(vals["arr"].value, arr)

    def test_legacy_inline_checkpoint_still_loads(self, store, runner):
        """Pre-overhaul checkpoints embed payloads; they must resume."""
        from repro.core.process import _deserialize_inputs

        inline = {"x": {"__data__": {"type": "int", "value": 9}, "pk": 1}}
        vals = _deserialize_inputs(inline, store)
        assert vals["x"].value == 9


# ---------------------------------------------------------------------------
# archives over blob-backed profiles
# ---------------------------------------------------------------------------

class TestArchiveWithBlobs:
    def test_roundtrip_byte_identical_with_blobs(self, tmp_path):
        from repro.core import calcfunction
        from repro.engine.runner import Runner, set_default_runner
        from repro.provenance.archive import export_archive, import_archive

        @calcfunction
        def big(seed):
            rng = np.random.default_rng(int(seed))
            return ArrayData(rng.normal(size=4096))

        st_a = ProvenanceStore(str(tmp_path / "a.db"), inline_threshold=1024)
        set_default_runner(Runner(store=st_a))
        try:
            big(Int(1))
        finally:
            set_default_runner(None)
        # source payloads really are blob-backed
        assert st_a.repository.stats()["blobs"] >= 1

        arch1 = str(tmp_path / "one.zip")
        m1 = export_archive(st_a, arch1)

        st_b = ProvenanceStore(str(tmp_path / "b.db"), inline_threshold=1024)
        res = import_archive(st_b, arch1)
        assert res.nodes_imported == m1["nodes"]
        # imported array went through the repository, same digest
        assert (sorted(st_b.repository.digests()) ==
                sorted(st_a.repository.digests()))

        arch2 = str(tmp_path / "two.zip")
        m2 = export_archive(st_b, arch2)
        assert m1["content_digest"] == m2["content_digest"]
        with open(arch1, "rb") as f1, open(arch2, "rb") as f2:
            assert f1.read() == f2.read()
        st_a.close()
        st_b.close()

    def test_reimport_is_noop(self, tmp_path):
        from repro.provenance.archive import export_archive, import_archive

        st_a = ProvenanceStore(str(tmp_path / "a.db"), inline_threshold=64)
        v = st_a.store_data(ArrayData(np.arange(512, dtype=np.float64)))
        assert v.is_stored
        arch = str(tmp_path / "a.zip")
        export_archive(st_a, arch)
        st_b = ProvenanceStore(str(tmp_path / "b.db"), inline_threshold=64)
        assert import_archive(st_b, arch).nodes_imported == 1
        again = import_archive(st_b, arch)
        assert again.nodes_imported == 0 and again.nodes_existing == 1
        st_a.close()
        st_b.close()


# ---------------------------------------------------------------------------
# cache hits on blob-backed arrays
# ---------------------------------------------------------------------------

class TestBlobCacheHit:
    def test_cache_hit_reuses_blob(self, tmp_path):
        from repro.caching.config import enable_caching
        from repro.core import calcfunction
        from repro.engine.runner import Runner, set_default_runner

        @calcfunction
        def expensive(seed):
            rng = np.random.default_rng(int(seed))
            return ArrayData(rng.normal(size=4096))

        st = ProvenanceStore(str(tmp_path / "p.db"), inline_threshold=1024)
        set_default_runner(Runner(store=st))
        try:
            with enable_caching():
                cold = expensive(Int(3))
                blobs_after_cold = st.repository.stats()["blobs"]
                warm = expensive(Int(3))
            assert np.array_equal(cold.value, warm.value)
            assert warm.pk != cold.pk          # clone, new node
            # clone's payload dedups onto the same blob — no new content
            assert st.repository.stats()["blobs"] == blobs_after_cold
            creators = st.incoming(warm.pk, LinkType.CREATE)
            attrs = json.loads(
                st.get_node(creators[0][0])["attributes"] or "{}")
            assert "cached_from" in attrs
        finally:
            set_default_runner(None)
            st.close()


# ---------------------------------------------------------------------------
# concurrent writers (separate OS processes) + live reader
# ---------------------------------------------------------------------------

_WRITER = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.core import Int, ArrayData, calcfunction
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import ProvenanceStore

    @calcfunction
    def work(seed, arr):
        return ArrayData(np.asarray(arr.value) + int(seed))

    store = ProvenanceStore(sys.argv[1], inline_threshold=1024)
    set_default_runner(Runner(store=store))
    base = int(sys.argv[2])
    for i in range(int(sys.argv[3])):
        work(Int(base + i), ArrayData(np.arange(512, dtype=np.float64)))
    store.close()
    print("done", base)
""")


@pytest.mark.slow
class TestConcurrentWriters:
    def test_two_writers_one_reader(self, tmp_path):
        from repro.provenance.archive import compute_closure

        db = str(tmp_path / "shared.db")
        per_writer = 8
        env = dict(os.environ,
                   PYTHONPATH=SRC + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        procs = [subprocess.Popen(
                    [sys.executable, "-c", _WRITER, db, str(base),
                     str(per_writer)],
                    env=env, stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE)
                 for base in (1000, 2000)]

        # reader: traverse through WAL while both writers are live
        reader = ProvenanceStore(db, inline_threshold=1024)
        reads = 0
        while any(p.poll() is None for p in procs):
            rows = reader.unfinished_processes()
            procs_now = [r["pk"] for r in QueryBuilder(reader)
                         .nodes("process").project("pk").all()]
            if procs_now:
                closure = compute_closure(reader, procs_now[:3])
                assert closure
            reads += 1
            assert rows is not None
        for p in procs:
            out, err = p.communicate(timeout=60)
            assert p.returncode == 0, err.decode()

        # all writes landed: 2 writers x N calcs, each calc = 1 process
        # node + 2 input data + 1 output data
        n_procs = QueryBuilder(reader).nodes("process").count()
        assert n_procs == 2 * per_writer
        assert reader.count_nodes() == 2 * per_writer * 4
        assert reads > 0
        # every payload rehydrates (blobs written by other OS processes)
        for r in (QueryBuilder(reader).nodes("data")
                  .project("pk", "node_type").all()):
            if r["node_type"] == "data":
                reader.load_data(r["pk"])
        reader.close()
