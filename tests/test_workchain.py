"""WorkChain outline DSL, context, awaitables, checkpoint resume
(paper §II.B.3)."""

import asyncio

import pytest

from repro.core import (
    ExitCode, Int, Process, ProcessState, ToContext, WorkChain, append_,
    calcfunction, if_, return_, while_,
)
from repro.provenance.store import LinkType, NodeType, QueryBuilder


class Counter(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n", valid_type=Int, default=Int(5))
        spec.output("total", valid_type=Int)
        spec.outline(
            cls.setup,
            while_(cls.below)(cls.bump),
            cls.finish,
        )

    def setup(self):
        self.ctx.i = 0

    def below(self):
        return self.ctx.i < self.inputs["n"].value

    def bump(self):
        self.ctx.i += 1

    def finish(self):
        self.out("total", Int(self.ctx.i))


def test_while_loop(store, runner):
    outputs, proc = runner.run(Counter, {"n": Int(7)})
    assert proc.is_finished_ok
    assert outputs["total"].value == 7


class Conditional(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("x", valid_type=Int)
        spec.output("kind", valid_type=Int)
        spec.outline(
            if_(cls.is_big)(cls.set_big)
            .elif_(cls.is_medium)(cls.set_medium)
            .else_(cls.set_small),
        )

    def is_big(self):
        return self.inputs["x"].value > 100

    def is_medium(self):
        return self.inputs["x"].value > 10

    def set_big(self):
        self.out("kind", Int(2))

    def set_medium(self):
        self.out("kind", Int(1))

    def set_small(self):
        self.out("kind", Int(0))


@pytest.mark.parametrize("x,expected", [(1000, 2), (50, 1), (3, 0)])
def test_if_elif_else(store, runner, x, expected):
    outputs, proc = runner.run(Conditional, {"x": Int(x)})
    assert outputs["kind"].value == expected


class EarlyReturn(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.outline(
            cls.first,
            return_,
            cls.never,
        )

    def first(self):
        self.ctx.ran = ["first"]

    def never(self):
        self.ctx.ran.append("never")


def test_return_stops_outline(store, runner):
    outputs, proc = runner.run(EarlyReturn, {})
    assert proc.is_finished_ok
    assert proc.ctx.ran == ["first"]


class Aborter(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.exit_code(418, "ERROR_I_AM_A_TEAPOT",
                       "the workchain experienced an identity crisis")
        spec.outline(cls.abort_straightaway)

    def abort_straightaway(self):
        self.report("work chain will be terminated")
        return self.exit_codes.ERROR_I_AM_A_TEAPOT


def test_exit_code_abort(store, runner):
    outputs, proc = runner.run(Aborter, {})
    assert proc.state is ProcessState.FINISHED
    assert proc.exit_code.status == 418
    assert store.get_node(proc.pk)["exit_status"] == 418


class IntReturnAbort(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.outline(cls.go)

    def go(self):
        return 404


def test_integer_abort(store, runner):
    outputs, proc = runner.run(IntReturnAbort, {})
    assert proc.exit_code.status == 404


@calcfunction
def double(a):
    return Int(a.value * 2)


class Child(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("a", valid_type=Int)
        spec.output("doubled", valid_type=Int)
        spec.outline(cls.go)

    def go(self):
        self.out("doubled", double(self.inputs["a"]))


class Parent(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.expose_inputs(Child)
        spec.output("result", valid_type=Int)
        spec.outline(cls.launch, cls.collect)

    def launch(self):
        child = self.submit(Child, **self.exposed_inputs(Child))
        return ToContext(child=child)

    def collect(self):
        assert self.ctx.child.is_finished_ok
        self.out("result", self.ctx.child.outputs["doubled"])


def test_tocontext_and_expose(store, runner):
    outputs, proc = runner.run(Parent, {"a": Int(21)})
    assert outputs["result"].value == 42
    # CALL_WORK link parent -> child
    calls = store.outgoing(proc.pk, LinkType.CALL_WORK)
    assert len(calls) == 1


class FanOut(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n", valid_type=Int, default=Int(4))
        spec.output("sum", valid_type=Int)
        spec.outline(cls.launch_all, cls.collect)

    def launch_all(self):
        for i in range(self.inputs["n"].value):
            self.to_context(children=append_(self.submit(Child,
                                                         a=Int(i))))

    def collect(self):
        total = sum(c.outputs["doubled"].value for c in self.ctx.children)
        self.out("sum", Int(total))


def test_append_parallel_children(store, runner):
    outputs, proc = runner.run(FanOut, {"n": Int(4)})
    assert outputs["sum"].value == 2 * (0 + 1 + 2 + 3)
    assert len(proc.ctx.children) == 4


def test_missing_required_output_fails(store, runner):
    class Forgetful(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.output("must_have", valid_type=Int)
            spec.outline(cls.noop)

        def noop(self):
            pass

    outputs, proc = runner.run(Forgetful, {})
    assert not proc.is_finished_ok
    assert proc.exit_code.status == 11


class TwoPhase(WorkChain):
    """Module-level (checkpoint recreation imports the class by path,
    exactly like AiiDA requires registered, importable process classes)."""

    executed = []
    crash_once = True

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.output("v", valid_type=Int)
        spec.outline(cls.phase1, cls.phase2)

    def phase1(self):
        self.ctx.v = 41
        TwoPhase.executed.append("phase1")

    def phase2(self):
        if TwoPhase.crash_once:
            TwoPhase.crash_once = False
            TwoPhase.executed.append("phase2_crash")
            raise KeyboardInterrupt  # hard worker death mid-step
        TwoPhase.executed.append("phase2")
        self.out("v", Int(self.ctx.v + 1))


class WhileCrash(WorkChain):
    """Crashes inside the while_ body on a chosen iteration — exercises
    stepper save/load of a partially-executed loop body."""

    crash_at = None
    executed = []

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n", valid_type=Int, default=Int(4))
        spec.output("trace", valid_type=Int)
        spec.outline(
            cls.setup,
            while_(cls.below)(
                cls.first_half,
                cls.second_half,
            ),
            cls.finish,
        )

    def setup(self):
        self.ctx.i = 0
        self.ctx.halves = 0

    def below(self):
        return self.ctx.i < self.inputs["n"].value

    def first_half(self):
        self.ctx.halves += 1
        WhileCrash.executed.append(f"first[{self.ctx.i}]")

    def second_half(self):
        if WhileCrash.crash_at == self.ctx.i:
            WhileCrash.crash_at = None
            WhileCrash.executed.append(f"crash[{self.ctx.i}]")
            raise KeyboardInterrupt   # hard worker death mid-body
        WhileCrash.executed.append(f"second[{self.ctx.i}]")
        self.ctx.halves += 1
        self.ctx.i += 1

    def finish(self):
        self.out("trace", Int(self.ctx.halves))


def test_stepper_resume_mid_while_body(store, runner):
    """Kill a chain between the two steps of a while_ body; the resumed
    stepper must re-enter the SAME iteration at the interrupted step —
    not re-run the completed first half, not skip the iteration."""
    WhileCrash.executed = []
    WhileCrash.crash_at = 2
    proc = WhileCrash(inputs={"n": Int(4)}, runner=runner)
    pk = proc.pk
    with pytest.raises(KeyboardInterrupt):
        runner.loop.run_until_complete(proc.step_until_terminated())

    ckpt = store.load_checkpoint(pk)
    assert ckpt is not None
    resumed = Process.recreate_from_checkpoint(ckpt, runner=runner)
    # position restored mid-loop: iteration 2, first half already done
    assert resumed.ctx.i == 2 and resumed.ctx.halves == 5
    runner.loop.run_until_complete(resumed.step_until_terminated())
    assert resumed.is_finished_ok
    # 4 iterations x 2 halves, none double-counted across the crash
    assert resumed.outputs["trace"].value == 8
    assert WhileCrash.executed == [
        "first[0]", "second[0]", "first[1]", "second[1]",
        "first[2]", "crash[2]",            # original run dies here
        "second[2]", "first[3]", "second[3]",   # resume: same iteration,
    ]                                           # interrupted step only


def test_checkpoint_resume_mid_outline(store, runner):
    """Kill a workchain between steps; recreate from checkpoint; the
    context and outline position survive (paper §II.B.3.c). phase1 must
    NOT re-run on resume — only the step that was interrupted does."""
    TwoPhase.executed = []
    TwoPhase.crash_once = True
    proc = TwoPhase(inputs={}, runner=runner)
    pk = proc.pk
    with pytest.raises(KeyboardInterrupt):
        runner.loop.run_until_complete(proc.step_until_terminated())

    # Simulated restart: a fresh process object from the DB checkpoint
    # (saved after phase1 completed, before phase2 crashed).
    ckpt = store.load_checkpoint(pk)
    assert ckpt is not None
    resumed = Process.recreate_from_checkpoint(ckpt, runner=runner)
    assert resumed.ctx.v == 41
    runner.loop.run_until_complete(resumed.step_until_terminated())
    assert resumed.is_finished_ok
    assert resumed.outputs["v"].value == 42
    # phase1 ran exactly once; phase2 re-ran after the crash
    assert TwoPhase.executed == ["phase1", "phase2_crash", "phase2"]
