"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st  # noqa: E501

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_chunk.ops import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 5e-5


# ---------------------------------------------------------------------------
# flash attention sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,hd", [
    (1, 64, 4, 4, 32),     # MHA
    (2, 128, 8, 2, 64),    # GQA 4x
    (1, 96, 6, 1, 32),     # MQA, non-pow2 seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, s, h, hkv, hd, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_local_window(window):
    b, s, h, hd = 1, 128, 2, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_kv=32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_flash_attention_gradients_match_ref():
    b, s, h, hkv, hd = 1, 64, 4, 2, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, hd)), jnp.float32)

    def lk(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, block_q=32,
                                       block_kv=32) ** 2)

    def lr(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(lk, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-3)


def test_flash_attention_softcap():
    b, s, h, hd = 1, 64, 2, 32
    q = jnp.asarray(RNG.normal(0, 2, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 2, (b, s, h, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=10.0,
                          block_q=32, block_kv=32)
    ref = attention_ref(q, k, v, causal=True, softcap=10.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


# ---------------------------------------------------------------------------
# decode attention sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,hd,smax", [
    (2, 4, 4, 32, 128),
    (3, 8, 2, 64, 256),
    (1, 4, 1, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_shapes(b, h, hkv, hd, smax, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), dtype)
    lens = jnp.asarray(RNG.integers(1, smax + 1, (b,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_kv=64)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@given(st.integers(min_value=1, max_value=200))
@settings(max_examples=8, deadline=None)
def test_decode_attention_ragged_lengths_property(kv_len):
    """Cache entries beyond kv_len never influence the output."""
    b, h, hd, smax = 1, 2, 32, 256
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), jnp.float32)
    k = np.asarray(RNG.normal(0, 1, (b, smax, h, hd)), np.float32)
    v = np.asarray(RNG.normal(0, 1, (b, smax, h, hd)), np.float32)
    k2, v2 = k.copy(), v.copy()
    k2[:, kv_len:] = 999.0      # poison the dead region
    v2[:, kv_len:] = -999.0
    out1 = decode_attention(q, jnp.asarray(k), jnp.asarray(v),
                            jnp.int32(kv_len), block_kv=64)
    out2 = decode_attention(q, jnp.asarray(k2), jnp.asarray(v2),
                            jnp.int32(kv_len), block_kv=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("h,hkv", [(8, 4), (8, 1), (4, 2), (6, 3)])
@pytest.mark.parametrize("hd", [32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_gqa_headdim_sweep(h, hkv, hd, dtype):
    """GQA group ratios (h != hkv, incl. MQA and non-pow2 heads) across
    head dims and dtypes, with ragged per-row lengths."""
    b, smax = 2, 128
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), dtype)
    lens = jnp.asarray([31, smax], jnp.int32)
    out = decode_attention(q, k, v, lens, block_kv=64)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_kvlen_edge_cases():
    """One batch mixing the ragged-length edges: a single live entry, a
    length that is no multiple of block_kv, Smax-1 and exactly Smax."""
    b, h, hd, smax = 4, 4, 32, 256
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, smax, h, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, smax, h, hd)), jnp.float32)
    lens = jnp.asarray([1, 130, smax - 1, smax], jnp.int32)
    out = decode_attention(q, k, v, lens, block_kv=128)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)
    # kv_len=1 must reproduce v[:, 0] exactly (softmax over one entry)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(v[0, 0]),
                               atol=5e-5)


def test_decode_attention_kvlen_zero_is_zero_output():
    """kv_len=0 (a slot with an empty cache) must yield a finite all-zero
    row, not NaNs. Kernel-only: the jnp oracle softmaxes over an all-masked
    row and returns garbage for length 0, so there is nothing to diff."""
    b, h, hd, smax = 2, 4, 32, 128
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, smax, h, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, smax, h, hd)), jnp.float32)
    lens = jnp.asarray([0, 64], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lens, block_kv=64))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out[0], np.zeros((h, hd)), atol=0)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out[1], np.asarray(ref[1]), atol=5e-5)


@pytest.mark.parametrize("block_kv", [128, 256, 512])
def test_decode_attention_block_kv_invariance(block_kv):
    """The KV tile size is a pure scheduling knob: results must match the
    oracle bit-for-tolerance at every block_kv."""
    b, h, hkv, hd, smax = 2, 4, 2, 64, 512
    q = jnp.asarray(RNG.normal(0, 1, (b, h, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, smax, hkv, hd)), jnp.float32)
    lens = jnp.asarray([200, 511], jnp.int32)
    out = decode_attention(q, k, v, lens, block_kv=block_kv)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


# ---------------------------------------------------------------------------
# rglru scan sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,bt,bd", [
    (1, 64, 32, 16, 32),
    (2, 128, 96, 32, 32),
    (1, 96, 48, 32, 16),    # non-pow2 sizes
])
def test_rglru_scan_shapes(b, s, d, bt, bd):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (b, s, d)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 0.1, (b, s, d)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (b, d)), jnp.float32)
    hs, hl = rglru_scan(a, x, h0, block_t=bt, block_d=bd)
    hs_r, hl_r = rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_r), atol=1e-5)


def test_rglru_scan_gradients():
    b, s, d = 1, 64, 32
    a = jnp.asarray(RNG.uniform(0.7, 0.99, (b, s, d)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 0.1, (b, s, d)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (b, d)), jnp.float32)

    def lk(a, x, h0):
        hs, hl = rglru_scan(a, x, h0, block_t=16, block_d=16)
        return jnp.sum(hs ** 2) + jnp.sum(hl)

    def lr(a, x, h0):
        hs, hl = rglru_scan_ref(a, x, h0)
        return jnp.sum(hs ** 2) + jnp.sum(hl)

    gk = jax.grad(lk, argnums=(0, 1, 2))(a, x, h0)
    gr = jax.grad(lr, argnums=(0, 1, 2))(a, x, h0)
    for g1, g2 in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=4, max_value=32))
@settings(max_examples=8, deadline=None)
def test_rglru_block_size_invariance_property(nblocks, bt):
    """The blocked scan result is independent of the block size."""
    b, d = 1, 16
    s = nblocks * bt
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (b, s, d)), jnp.float32)
    x = jnp.asarray(RNG.normal(0, 0.2, (b, s, d)), jnp.float32)
    h0 = jnp.zeros((b, d), jnp.float32)
    hs1, _ = rglru_scan(a, x, h0, block_t=bt, block_d=d)
    hs2, _ = rglru_scan(a, x, h0, block_t=s, block_d=d)
    np.testing.assert_allclose(np.asarray(hs1), np.asarray(hs2), atol=1e-5)


# ---------------------------------------------------------------------------
# mlstm chunk sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,hd,chunk", [
    (1, 2, 64, 32, 16),
    (2, 3, 64, 32, 32),
    (1, 1, 128, 64, 64),
])
def test_mlstm_chunk_shapes(b, h, s, hd, chunk):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    li = jnp.asarray(RNG.normal(0, 1, (b, h, s)), jnp.float32)
    lf = jnp.asarray(-np.abs(RNG.normal(1, 0.5, (b, h, s))), jnp.float32)
    C0 = jnp.zeros((b, h, hd, hd))
    n0 = jnp.zeros((b, h, hd))
    m0 = jnp.full((b, h), -1e30)
    hs, (C, n, m) = mlstm_chunk(q, k, v, li, lf, C0, n0, m0, chunk=chunk)
    hs_r, (Cr, nr, mr) = mlstm_ref(q, k, v, li, lf, C0, n0, m0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Cr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-5)


def test_mlstm_carried_state_continuation():
    """Processing [first half -> state -> second half] equals processing
    the full sequence at once."""
    b, h, s, hd = 1, 2, 64, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, hd)), jnp.float32)
    li = jnp.asarray(RNG.normal(0, 1, (b, h, s)), jnp.float32)
    lf = jnp.asarray(-np.abs(RNG.normal(1, 0.5, (b, h, s))), jnp.float32)
    zeroC = jnp.zeros((b, h, hd, hd))
    zeron = jnp.zeros((b, h, hd))
    zerom = jnp.full((b, h), -1e30)
    full, _ = mlstm_chunk(q, k, v, li, lf, zeroC, zeron, zerom, chunk=16)
    h1, (C, n, m) = mlstm_chunk(q[:, :, :32], k[:, :, :32], v[:, :, :32],
                                li[:, :, :32], lf[:, :, :32],
                                zeroC, zeron, zerom, chunk=16)
    h2, _ = mlstm_chunk(q[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                        li[:, :, 32:], lf[:, :, 32:], C, n, m, chunk=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(full[:, :, :32]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, :, 32:]),
                               atol=1e-4)
