"""Per-architecture smoke tests: reduced configs of the SAME family run a
forward/train step on CPU asserting output shapes + no NaNs; serving path
(prefill + decode) is exercised for every arch. The FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models.registry import SHAPES, ShapeCell, build
from repro.serving.serve import make_decode_step, make_prefill_step
from repro.training.train_step import (
    TrainConfig, init_train_state, make_train_step,
)

ARCHS = [a for a in ARCH_IDS if a != "aiida-demo-110m"]
RNG = np.random.default_rng(0)


def _batch_for(bundle, b, s):
    cfg = bundle.cfg
    cell = ShapeCell("smoke", "train", s, b)
    out = {}
    for k, v in bundle.batch_struct(cell).items():
        if v.dtype == jnp.int32:
            out[k] = jnp.asarray(RNG.integers(0, cfg.vocab_size, v.shape),
                                 jnp.int32)
        else:
            out[k] = jnp.asarray(RNG.normal(0, 1, v.shape), v.dtype)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(bundle, 2, 64)

    loss, metrics = bundle.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss {loss}"

    tcfg = TrainConfig()
    state = init_train_state(bundle, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(bundle, tcfg))
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"])), f"{arch}: train loss {m['loss']}"
    assert bool(jnp.isfinite(m["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually moved (some leaves may legitimately have ~0 grads;
    # check the global update magnitude)
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(state["params"])))
    assert delta > 1e-3, f"{arch}: optimizer did not move params ({delta})"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_serving_path(arch):
    cfg = reduced_config(arch)
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch_for(bundle, b, s)
    cache = bundle.init_cache(b, s + 8)
    prefill = jax.jit(make_prefill_step(bundle))
    tok, cache = prefill(params, batch, cache)
    assert tok.shape == (b, 1)
    assert 0 <= int(tok.min()) and int(tok.max()) < cfg.vocab_size
    decode = jax.jit(make_decode_step(bundle))
    for i in range(3):
        tok, cache = decode(params, cache, tok, jnp.asarray(s + i))
        assert tok.shape == (b, 1)
        assert 0 <= int(tok.min()) and int(tok.max()) < cfg.vocab_size


def test_microbatched_grad_accumulation_matches_single():
    arch = "qwen2-0.5b"
    cfg = reduced_config(arch)
    bundle = build(cfg)
    batch = _batch_for(bundle, 4, 32)
    s1 = init_train_state(bundle, TrainConfig(microbatches=1),
                          jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(bundle, TrainConfig(microbatches=1)))
    step4 = jax.jit(make_train_step(bundle, TrainConfig(microbatches=4)))
    s1, m1 = step1(s1, batch)
    s2, m2 = step4(s2, batch)
    # same data, same update (up to accumulation-order float noise)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    p1 = np.asarray(jax.tree.leaves(s1["params"])[0], np.float32)
    p2 = np.asarray(jax.tree.leaves(s2["params"])[0], np.float32)
    np.testing.assert_allclose(p1, p2, atol=5e-4)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned numbers."""
    expect = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, vocab) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == vocab, arch


def test_moe_configs():
    grok = get_config("grok-1-314b")
    assert grok.num_experts == 8 and grok.num_experts_per_tok == 2
    moon = get_config("moonshot-v1-16b-a3b")
    assert moon.num_experts == 64 and moon.num_experts_per_tok == 6


def test_long_context_applicability():
    cell = SHAPES["long_500k"]
    runs = {a: build(get_config(a)).supports_cell(cell)[0] for a in ARCHS}
    assert runs["recurrentgemma-2b"] and runs["xlstm-350m"]
    assert sum(runs.values()) == 2   # everyone else skips


def test_chunked_attention_matches_direct():
    """The memory-efficient chunked path is numerically the direct path."""
    from repro.models import attention as A
    cfg = reduced_config("qwen3-4b")
    import jax.random as jr
    p = {
        k: v for k, v in zip(
            ["wq", "wk", "wv", "wo", "q_norm", "k_norm"],
            [0.02 * jr.normal(jr.PRNGKey(i), s) for i, s in enumerate([
                (cfg.d_model, cfg.num_heads, cfg.hd),
                (cfg.d_model, cfg.num_kv_heads, cfg.hd),
                (cfg.d_model, cfg.num_kv_heads, cfg.hd),
                (cfg.num_heads, cfg.hd, cfg.d_model),
                (cfg.hd,), (cfg.hd,)])])
    }
    x = jr.normal(jr.PRNGKey(9), (2, 64, cfg.d_model), jnp.float32)
    pos = jnp.arange(64, dtype=jnp.int32)
    cfg_direct = cfg.replace(attn_impl="direct", dtype="float32")
    cfg_chunk = cfg.replace(attn_impl="chunked", attn_kv_block=16,
                            dtype="float32")
    out_d = A.attn_forward(cfg_direct, p, x, pos, causal=True)
    out_c = A.attn_forward(cfg_chunk, p, x, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                               atol=2e-5)
