"""End-to-end behaviour: the engine orchestrating real (reduced) training
jobs, with error handling + provenance, and serving consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calcjobs import TPUTrainJob
from repro.configs import reduced_config
from repro.core import Dict, Int, ToContext, WorkChain, append_, while_
from repro.models.registry import build
from repro.provenance.store import LinkType, NodeType, QueryBuilder
from repro.serving.serve import make_decode_step, make_prefill_step


class SweepWorkChain(WorkChain):
    """The canonical high-throughput pattern: fan out N training jobs with
    different seeds, collect the best."""

    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n_jobs", valid_type=Int, default=Int(3))
        spec.input("config", valid_type=Dict)
        spec.output("best_loss", valid_type=Dict)
        spec.outline(cls.launch, cls.collect)

    def launch(self):
        base = dict(self.inputs["config"].value)
        for seed in range(self.inputs["n_jobs"].value):
            cfg = dict(base)
            cfg["seed"] = seed
            self.to_context(jobs=append_(
                self.submit(TPUTrainJob, config=Dict(cfg))))

    def collect(self):
        best = None
        for job in self.ctx.jobs:
            assert job.is_finished_ok
            m = job.outputs["metrics"].value
            if best is None or m["final_loss"] < best["final_loss"]:
                best = m
        self.out("best_loss", Dict(best))


def test_sweep_workchain_end_to_end(store, runner):
    outputs, proc = runner.run(SweepWorkChain, {
        "n_jobs": Int(3),
        "config": Dict({"arch": "qwen2-0.5b", "steps": 2, "batch": 1,
                        "seq": 16}),
    })
    assert proc.is_finished_ok
    assert outputs["best_loss"].value["final_loss"] > 0
    # provenance: 1 workchain -> 3 calcjobs, each with retrieved+metrics
    assert QueryBuilder(store).nodes(NodeType.CALC_JOB).count() == 3
    calls = store.outgoing(proc.pk, LinkType.CALL_CALC)
    assert len(calls) == 3
    # all nodes terminal; no dangling unfinished processes
    assert store.unfinished_processes() == []


def test_serving_matches_teacher_forcing():
    """Greedy decode from a prefilled cache must equal argmax over the
    full-forward logits at the same positions (cache correctness)."""
    cfg = reduced_config("qwen3-4b").replace(dtype="float32",
                                             param_dtype="float32")
    bundle = build(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 24
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)

    # full forward logits
    from repro.models.transformer import lm_forward
    logits, _ = lm_forward(cfg, params, {"tokens": tokens})
    full_next = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)

    cache = bundle.init_cache(b, s + 8)
    prefill = make_prefill_step(bundle)
    tok, cache = prefill(params, {"tokens": tokens}, cache)
    np.testing.assert_array_equal(np.asarray(tok[:, 0]),
                                  np.asarray(full_next))

    # one decode step == forward over s+1 tokens
    decode = make_decode_step(bundle)
    tok2, cache = decode(params, cache, tok, jnp.asarray(s))
    tokens_ext = jnp.concatenate([tokens, tok], axis=1)
    logits_ext, _ = lm_forward(cfg, params, {"tokens": tokens_ext})
    expect = jnp.argmax(logits_ext[:, -1, :cfg.vocab_size], axis=-1)
    np.testing.assert_array_equal(np.asarray(tok2[:, 0]), np.asarray(expect))


def test_loss_decreases_under_training():
    """~30 steps on a reduced config: loss goes down on a fixed batch."""
    from repro.training.optim import OptimConfig
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)

    cfg = reduced_config("qwen2-0.5b")
    bundle = build(cfg)
    tcfg = TrainConfig(optim=OptimConfig(lr=1e-3, warmup_steps=5,
                                         total_steps=40))
    state = init_train_state(bundle, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (2, 65), dtype=np.int32)
    batch = {"tokens": jnp.asarray(tokens[:, :-1]),
             "labels": jnp.asarray(tokens[:, 1:])}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_pause_play_kill_rpc(store, runner):
    """External control via RPC (paper §III.C.b)."""
    import asyncio

    class Slow(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.outline(while_(cls.forever)(cls.tick))

        def forever(self):
            return True

        def tick(self):
            self.ctx["n"] = self.ctx.get("n", 0) + 1

    async def main():
        handle = runner.submit(Slow, {})
        await asyncio.sleep(0.05)
        runner.control(handle.pk, "kill", message="enough")
        await asyncio.wait_for(handle.process.wait_done(), timeout=10)
        return handle.process

    proc = runner.loop.run_until_complete(main())
    assert proc.state.value == "killed"
    assert store.get_node(proc.pk)["process_state"] == "killed"
