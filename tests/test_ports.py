"""Port / PortNamespace / ProcessSpec behaviour (paper §II.A)."""

import pytest
from _hypothesis_compat import given, strategies as st

from repro.core import Int, Float, ProcessSpec
from repro.core.ports import InputPort, PortNamespace


def test_port_validation_type():
    p = InputPort("a", valid_type=Int)
    assert p.validate(Int(3)) is None
    err = p.validate(Float(3.0))
    assert err is not None and "a" in err


def test_port_custom_validator():
    p = InputPort("a", valid_type=Int,
                  validator=lambda v: None if v.value > 0 else "not positive")
    assert p.validate(Int(1)) is None
    assert "not positive" in p.validate(Int(-1))


def test_port_default_and_required():
    p = InputPort("a", valid_type=Int, default=Int(2))
    assert not p.required
    assert p.default.value == 2
    q = InputPort("b", valid_type=Int)
    assert q.required
    assert "required" in q.validate(None)


def test_nested_namespace_creation():
    ns = PortNamespace("inputs")
    ns["nested.input.namespace"] = InputPort("x", valid_type=Int)
    assert isinstance(ns["nested"], PortNamespace)
    assert isinstance(ns["nested.input"], PortNamespace)
    assert isinstance(ns["nested.input.namespace"], InputPort)


def test_namespace_rejects_undeclared():
    ns = PortNamespace("inputs")
    ns["a"] = InputPort("a", valid_type=Int, required=False)
    assert ns.validate({"a": Int(1), "zz": Int(2)}) is not None
    ns.dynamic = True
    assert ns.validate({"a": Int(1), "zz": Int(2)}) is None


def test_spec_declarative_override():
    """Paper listing 3: later declarations override earlier ones."""
    spec = ProcessSpec()
    spec.input("a", valid_type=Int)
    spec.input("a", valid_type=Float)
    assert spec.inputs["a"].valid_type == (Float,)
    assert spec.validate_inputs({"a": Float(1.0)}) is None
    assert spec.validate_inputs({"a": Int(1)}) is not None


def test_spec_exit_codes():
    spec = ProcessSpec()
    spec.exit_code(418, "ERROR_I_AM_A_TEAPOT",
                   "the workchain experienced an identity crisis")
    ec = spec.exit_codes.ERROR_I_AM_A_TEAPOT
    assert ec.status == 418
    assert "identity crisis" in ec.message
    with pytest.raises(AttributeError):
        spec.exit_codes.NOPE
    with pytest.raises(ValueError):
        spec.exit_code(-1, "BAD", "negative")


def test_non_db_ports_excluded_from_projection():
    ns = PortNamespace("inputs")
    ns["a"] = InputPort("a", valid_type=Int)
    ns["meta"] = InputPort("meta", non_db=True, required=False)
    proj = ns.project({"a": Int(1), "meta": {"x": 1}})
    assert "meta" not in proj and "a" in proj


@given(st.lists(st.sampled_from("abcdefgh"), min_size=1, max_size=5,
                unique=True),
       st.sets(st.sampled_from("abcdefgh")))
def test_namespace_validate_required_property(declared, provided):
    """Validation fails iff some declared required port is missing."""
    ns = PortNamespace("inputs")
    for name in declared:
        ns[name] = InputPort(name, valid_type=Int)
    values = {name: Int(1) for name in provided if name in declared}
    err = ns.validate(values)
    missing = set(declared) - set(values)
    assert (err is None) == (not missing)


@given(st.integers(min_value=0, max_value=3),
       st.integers(min_value=1, max_value=4))
def test_namespace_nesting_depth_property(depth, width):
    ns = PortNamespace("root")
    path = ".".join(f"lvl{i}" for i in range(depth + 1))
    for w in range(width):
        ns[f"{path}.p{w}"] = InputPort(f"p{w}", valid_type=Int,
                                       required=False)
    node = ns
    for i in range(depth + 1):
        node = node[f"lvl{i}"]
        assert isinstance(node, PortNamespace)
    assert len(node) == width
