"""Port / PortNamespace / ProcessSpec behaviour (paper §II.A)."""

import pytest
from _hypothesis_compat import given, strategies as st

from repro.core import Int, Float, ProcessSpec, Str, WorkChain
from repro.core.ports import UNSPECIFIED, InputPort, PortNamespace


def test_port_validation_type():
    p = InputPort("a", valid_type=Int)
    assert p.validate(Int(3)) is None
    err = p.validate(Float(3.0))
    assert err is not None and "a" in err


def test_port_custom_validator():
    p = InputPort("a", valid_type=Int,
                  validator=lambda v: None if v.value > 0 else "not positive")
    assert p.validate(Int(1)) is None
    assert "not positive" in p.validate(Int(-1))


def test_port_default_and_required():
    p = InputPort("a", valid_type=Int, default=Int(2))
    assert not p.required
    assert p.default.value == 2
    q = InputPort("b", valid_type=Int)
    assert q.required
    assert "required" in q.validate(UNSPECIFIED)


def test_explicit_none_distinguished_from_absent():
    """A provided None is not the same as an absent key: optional typed
    ports must reject it, and required ports must say which happened."""
    req = InputPort("r", valid_type=Int)
    assert "was not provided" in req.validate(UNSPECIFIED)
    assert "explicitly passed None" in req.validate(None)

    opt = InputPort("o", valid_type=Int, required=False)
    assert opt.validate(UNSPECIFIED) is None          # absent: fine
    err = opt.validate(None)                          # explicit None: not an Int
    assert err is not None and "explicitly passed None" in err

    # untyped optional ports still accept an explicit None
    free = InputPort("f", required=False)
    assert free.validate(None) is None

    # NoneType in valid_type opts in to explicit None
    nullable = InputPort("n", valid_type=(Int, type(None)), required=False)
    assert nullable.validate(None) is None


def test_namespace_distinguishes_none_from_absent():
    ns = PortNamespace("inputs")
    ns["a"] = InputPort("a", valid_type=Int, required=False)
    ns["b"] = InputPort("b", valid_type=Int)
    assert ns.validate({"b": Int(1)}) is None                 # a absent: ok
    err = ns.validate({"a": None, "b": Int(1)})               # a explicit None
    assert err is not None and "explicitly passed None" in err and "a" in err
    err = ns.validate({"b": None})
    assert "required" in err and "explicitly passed None" in err


def test_port_serializer_wraps_raw_values():
    p = InputPort("n", valid_type=Int, serializer=Int)
    wrapped = p.serialize(3)
    assert isinstance(wrapped, Int) and wrapped.value == 3
    # already-valid values pass through untouched
    v = Int(5)
    assert p.serialize(v) is v
    # namespace-level walk serializes leaves, passes undeclared through
    ns = PortNamespace("inputs", dynamic=True)
    ns["n"] = p
    out = ns.serialize({"n": 7, "free": "x"})
    assert isinstance(out["n"], Int) and out["free"] == "x"


def test_absorb_deep_copies_ports():
    """expose_inputs must not alias Port objects between specs: mutating
    the exposing spec cannot leak into the source class (regression)."""

    class Source(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.input("x", valid_type=Int)
            spec.input("nested.y", valid_type=Int, default=Int(1))

    class Exposer(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.expose_inputs(Source, namespace="src")
            # override the exposed port after absorbing — must be local
            spec.input("src.x", valid_type=Str)

    exposed = Exposer.spec().inputs["src"]
    source = Source.spec().inputs
    assert exposed["x"] is not source["x"]
    assert exposed["nested"] is not source["nested"]
    assert exposed["nested.y"] is not source["nested.y"]
    # the override changed only the exposing spec
    assert Exposer.spec().inputs["src.x"].valid_type == (Str,)
    assert source["x"].valid_type == (Int,)
    # mutating a copied port does not touch the source either
    exposed["nested.y"].required = True
    assert source["nested.y"].required is False
    # deep-copied sentinel defaults survive with identity intact
    assert not exposed["x"].has_default
    assert exposed["nested.y"].default == Int(1)


def test_nested_namespace_creation():
    ns = PortNamespace("inputs")
    ns["nested.input.namespace"] = InputPort("x", valid_type=Int)
    assert isinstance(ns["nested"], PortNamespace)
    assert isinstance(ns["nested.input"], PortNamespace)
    assert isinstance(ns["nested.input.namespace"], InputPort)


def test_namespace_rejects_undeclared():
    ns = PortNamespace("inputs")
    ns["a"] = InputPort("a", valid_type=Int, required=False)
    assert ns.validate({"a": Int(1), "zz": Int(2)}) is not None
    ns.dynamic = True
    assert ns.validate({"a": Int(1), "zz": Int(2)}) is None


def test_spec_declarative_override():
    """Paper listing 3: later declarations override earlier ones."""
    spec = ProcessSpec()
    spec.input("a", valid_type=Int)
    spec.input("a", valid_type=Float)
    assert spec.inputs["a"].valid_type == (Float,)
    assert spec.validate_inputs({"a": Float(1.0)}) is None
    assert spec.validate_inputs({"a": Int(1)}) is not None


def test_spec_exit_codes():
    spec = ProcessSpec()
    spec.exit_code(418, "ERROR_I_AM_A_TEAPOT",
                   "the workchain experienced an identity crisis")
    ec = spec.exit_codes.ERROR_I_AM_A_TEAPOT
    assert ec.status == 418
    assert "identity crisis" in ec.message
    with pytest.raises(AttributeError):
        spec.exit_codes.NOPE
    with pytest.raises(ValueError):
        spec.exit_code(-1, "BAD", "negative")


def test_non_db_ports_excluded_from_projection():
    ns = PortNamespace("inputs")
    ns["a"] = InputPort("a", valid_type=Int)
    ns["meta"] = InputPort("meta", non_db=True, required=False)
    proj = ns.project({"a": Int(1), "meta": {"x": 1}})
    assert "meta" not in proj and "a" in proj


@given(st.lists(st.sampled_from("abcdefgh"), min_size=1, max_size=5,
                unique=True),
       st.sets(st.sampled_from("abcdefgh")))
def test_namespace_validate_required_property(declared, provided):
    """Validation fails iff some declared required port is missing."""
    ns = PortNamespace("inputs")
    for name in declared:
        ns[name] = InputPort(name, valid_type=Int)
    values = {name: Int(1) for name in provided if name in declared}
    err = ns.validate(values)
    missing = set(declared) - set(values)
    assert (err is None) == (not missing)


@given(st.integers(min_value=0, max_value=3),
       st.integers(min_value=1, max_value=4))
def test_namespace_nesting_depth_property(depth, width):
    ns = PortNamespace("root")
    path = ".".join(f"lvl{i}" for i in range(depth + 1))
    for w in range(width):
        ns[f"{path}.p{w}"] = InputPort(f"p{w}", valid_type=Int,
                                       required=False)
    node = ns
    for i in range(depth + 1):
        node = node[f"lvl{i}"]
        assert isinstance(node, PortNamespace)
    assert len(node) == width
