"""Chaos subsystem: fault plans, seam behaviour, the invariant checker,
and the end-to-end scenarios (daemon workers + kill -9 + invariants)."""

import json
import time

import pytest

from repro.chaos import faults
from repro.chaos.faults import CATALOG, ChaosInjected, ChaosPlan
from repro.chaos.harness import SCENARIOS, run_scenario
from repro.chaos.invariants import check_store
from repro.core import Float, Int
from repro.provenance.store import NodeType


@pytest.fixture(autouse=True)
def _disarm_chaos():
    """Every test starts and ends with fault injection disabled."""
    faults.deactivate()
    yield
    faults.deactivate()


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_plan_spec_roundtrip():
    spec = ("seed=7;store.commit.pre:raise:nth=2;"
            "broker.deliver.pre:duplicate:p=0.5,max=3;"
            "process.flush.pre:delay:delay=0.1,once")
    plan = ChaosPlan.parse(spec)
    assert ChaosPlan.parse(plan.spec()).spec() == plan.spec()


def test_unknown_point_and_action_rejected():
    with pytest.raises(ValueError):
        ChaosPlan(seed=1).on("no.such.point", "raise")
    with pytest.raises(ValueError):
        ChaosPlan(seed=1).on("store.commit.pre", "segfault")
    # glob patterns are fine as long as they match something registered
    ChaosPlan(seed=1).on("broker.*", "delay", delay=0.01)


def test_nth_fires_exactly_once():
    plan = ChaosPlan(seed=1).on("store.commit.pre", "raise", nth=3)
    faults.activate(plan)
    faults.fault_point("store.commit.pre")
    faults.fault_point("store.commit.pre")
    with pytest.raises(ChaosInjected):
        faults.fault_point("store.commit.pre")
    for _ in range(10):
        faults.fault_point("store.commit.pre")  # never again
    assert plan.fired["store.commit.pre"] == 1


def test_probability_stream_deterministic():
    def fire_pattern(seed):
        plan = ChaosPlan(seed=seed).on("broker.deliver.pre", "duplicate",
                                       p=0.5)
        faults.activate(plan)
        pattern = [faults.fault_point("broker.deliver.pre") == "duplicate"
                   for _ in range(32)]
        faults.deactivate()
        return pattern

    assert fire_pattern(11) == fire_pattern(11)
    assert fire_pattern(11) != fire_pattern(12)


def test_max_caps_fires():
    plan = ChaosPlan(seed=1).on("broker.deliver.pre", "duplicate",
                                p=1.0, max=2)
    faults.activate(plan)
    results = [faults.fault_point("broker.deliver.pre")
               for _ in range(10)]
    assert results.count("duplicate") == 2


def test_delay_action_sleeps():
    plan = ChaosPlan(seed=1).on("store.commit.post", "delay", delay=0.05,
                                once=True)
    faults.activate(plan)
    t0 = time.monotonic()
    faults.fault_point("store.commit.post")
    assert time.monotonic() - t0 >= 0.04


def test_env_spec_resolution(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR,
                       "seed=3;store.commit.pre:raise:nth=1")
    faults.reset()  # back to lazy env resolution
    with pytest.raises(ChaosInjected):
        faults.fault_point("store.commit.pre")
    # deactivate() disarms even while the env var is still set — this is
    # what keeps the harness process itself out of the blast radius
    faults.deactivate()
    assert faults.fault_point("store.commit.pre") is None


def test_disabled_fault_point_returns_none():
    assert faults.fault_point("store.commit.pre") is None
    assert faults.active_plan() is None


# ---------------------------------------------------------------------------
# seams
# ---------------------------------------------------------------------------

def test_raise_in_commit_rolls_back_transaction(store):
    before = store._conn().execute("SELECT COUNT(*) FROM nodes").fetchone()[0]
    faults.activate(ChaosPlan(seed=1).on("store.commit.pre", "raise", nth=1))
    with pytest.raises(ChaosInjected):
        store.create_process_node(NodeType.CALC_FUNCTION, "Doomed",
                                  label="doomed")
    faults.deactivate()
    after = store._conn().execute("SELECT COUNT(*) FROM nodes").fetchone()[0]
    assert after == before  # the unit of work rolled back whole
    # and the store is healthy again afterwards
    pk = store.create_process_node(NodeType.CALC_FUNCTION, "Fine",
                                   label="fine")
    assert store.get_node(pk) is not None


def test_chaos_calc_runs_clean(store, runner):
    from repro.chaos.workloads import ChaosCalc

    outputs, proc = runner.run(ChaosCalc, {"steps": Int(2),
                                           "pause": Float(0.01)})
    assert proc.is_finished_ok
    assert outputs["result"].value == 2


# ---------------------------------------------------------------------------
# broker disconnect cleanup (fail-fast routing to dead workers)
# ---------------------------------------------------------------------------

class _FakeWriter:
    def __init__(self):
        self.frames = []

    def is_closing(self):
        return False

    def write(self, data):
        self.frames.append(data)


class _FakeTimer:
    def __init__(self):
        self.cancelled = False

    def cancel(self):
        self.cancelled = True


def test_drop_client_disowns_and_fails_rpcs(tmp_path):
    from repro.engine.broker import BrokerServer

    srv = BrokerServer(str(tmp_path / "broker.db"))
    dead, alive = "worker-dead", "worker-alive"
    w_alive = _FakeWriter()
    srv._clients[dead] = _FakeWriter()
    srv._clients[alive] = w_alive
    srv._last_beat[dead] = 0.0
    srv._rpc["process.7"] = dead
    srv._owners[7] = dead
    srv._owners[8] = alive
    t_to_dead, t_from_dead = _FakeTimer(), _FakeTimer()
    srv._pending_rpc["r1"] = (alive, dead)   # alive is awaiting dead
    srv._rpc_timers["r1"] = t_to_dead
    srv._pending_rpc["r2"] = (dead, alive)   # dead was awaiting alive
    srv._rpc_timers["r2"] = t_from_dead

    srv._drop_client(dead)

    # pks auto-disowned, live worker untouched
    assert 7 not in srv._owners and srv._owners[8] == alive
    assert "process.7" not in srv._rpc
    # both directions of pending RPC cleaned up, timers cancelled
    assert srv._pending_rpc == {}
    assert t_to_dead.cancelled and t_from_dead.cancelled
    # the surviving origin got a fail-fast error instead of a hang
    reply = json.loads(w_alive.frames[0].decode().strip())
    assert reply["rid"] == "r1"
    assert "disconnected" in reply["error"]
    # idempotent: the reaper and the connection handler may both fire
    assert srv.stats["clients_dropped"] == 1
    srv._drop_client(dead)
    assert srv.stats["clients_dropped"] == 1


# ---------------------------------------------------------------------------
# invariant checker
# ---------------------------------------------------------------------------

def _raw_process(store, state, *, checkpoint=None, exit_status=0,
                 attributes="{}"):
    with store._lock:
        cur = store._conn().execute(
            "INSERT INTO nodes (uuid, node_type, process_state, exit_status,"
            " checkpoint, attributes, ctime, mtime) VALUES (hex(randomblob("
            "16)), 'process.calcfunction', ?, ?, ?, ?, 0, 0)",
            (state, exit_status, checkpoint, attributes))
        store._conn().commit()
        return cur.lastrowid


def test_invariants_detect_injected_corruption(store):
    # terminal node with a surviving checkpoint (torn terminal txn)
    torn = _raw_process(store, "finished", checkpoint='{"x": 1}')
    # finished without an exit status
    _raw_process(store, "finished", exit_status=None)
    # resurrected: state recorded after a terminal entry
    _raw_process(store, "finished", attributes=json.dumps({
        "state_history": [["created", 1.0], ["finished", 2.0],
                          ["running", 3.0]]}))
    # kill requested but never honoured
    _raw_process(store, "running", attributes=json.dumps(
        {"kill_requested": "die"}))
    # dangling link + duplicate create links
    data = _raw_process(store, None)
    with store._lock:
        store._conn().execute(
            "INSERT INTO links (in_id, out_id, link_type, label) VALUES"
            f" ({torn}, 999999, 'create', 'ghost')")
        store._conn().executemany(
            "INSERT INTO links (in_id, out_id, link_type, label) VALUES"
            " (?, ?, 'create', 'result')",
            [(torn, data), (torn, data)])
        store._conn().commit()

    report = check_store(store, expected_pks=[torn, 12345])
    assert not report.ok
    kinds = {v.invariant for v in report.violations}
    assert {"terminal-checkpoint", "exit-status", "resurrected",
            "kill-durability", "dangling-link", "duplicate-output",
            "duplicate-create", "lost"} <= kinds


def test_invariants_pass_on_clean_run(store, runner):
    from repro.chaos.workloads import ChaosCalc

    _, proc = runner.run(ChaosCalc, {"steps": Int(1), "pause": Float(0.0)})
    report = check_store(store, expected_pks=[proc.pk])
    assert report.ok, report.summary()


# ---------------------------------------------------------------------------
# end-to-end scenarios (real daemon workers, real kill -9)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_end_to_end(name, tmp_path):
    result = run_scenario(name, seed=1, workdir=str(tmp_path / name))
    assert result.ok, result.summary()


@pytest.mark.slow
def test_scenario_reproducible_under_fixed_seed(tmp_path):
    a = run_scenario("crash-in-txn", seed=42, workdir=str(tmp_path / "a"))
    b = run_scenario("crash-in-txn", seed=42, workdir=str(tmp_path / "b"))
    assert a.ok, a.summary()
    assert b.ok, b.summary()
    # the seeded plan is byte-identical across runs; outcomes agree
    assert a.report.states == b.report.states
