"""QueryBuilder coverage (ISSUE 1 satellite): ordering, limiting,
first/count, and queries over the node_hash column."""

import pytest

from repro.provenance.store import NodeType, ProvenanceStore, QueryBuilder


@pytest.fixture()
def populated():
    store = ProvenanceStore(":memory:")
    pks = {}
    for i in range(5):
        pks[f"calc{i}"] = store.create_process_node(
            NodeType.CALC_FUNCTION, process_type="Adder",
            label=f"calc-{i}", node_hash=f"hash-{i % 2}")
    pks["work"] = store.create_process_node(
        NodeType.WORK_CHAIN, process_type="Chain", label="chain",
        node_hash=None)
    store.update_process(pks["calc0"], state="finished", exit_status=0)
    store.update_process(pks["calc1"], state="finished", exit_status=0)
    store.update_process(pks["calc2"], state="excepted", exit_status=999)
    return store, pks


class TestQueryBuilder:
    def test_count(self, populated):
        store, _ = populated
        assert QueryBuilder(store).count() == 6
        assert QueryBuilder(store).nodes("process").count() == 6
        assert QueryBuilder(store).nodes(NodeType.CALC_FUNCTION).count() == 5
        assert QueryBuilder(store).nodes(NodeType.DATA).count() == 0

    def test_order_by_pk_desc(self, populated):
        store, pks = populated
        rows = QueryBuilder(store).order_by("pk", desc=True).all()
        assert [r["pk"] for r in rows] == sorted(
            (r["pk"] for r in rows), reverse=True)
        assert rows[0]["pk"] == pks["work"]

    def test_order_by_rejects_unknown_field(self, populated):
        store, _ = populated
        with pytest.raises(AssertionError):
            QueryBuilder(store).order_by("attributes; DROP TABLE nodes")

    def test_order_by_mtime(self, populated):
        store, pks = populated
        # update_process bumps mtime, so the excepted node sorts last
        rows = QueryBuilder(store).order_by("mtime", desc=True).all()
        assert rows[0]["pk"] == pks["calc2"]

    def test_limit(self, populated):
        store, _ = populated
        assert len(QueryBuilder(store).limit(2).all()) == 2
        assert len(QueryBuilder(store).limit(100).all()) == 6

    def test_first(self, populated):
        store, pks = populated
        first = QueryBuilder(store).nodes(NodeType.CALC_FUNCTION) \
            .order_by("pk").first()
        assert first["pk"] == pks["calc0"]
        assert QueryBuilder(store).with_state("nonexistent").first() is None

    def test_filter_chaining(self, populated):
        store, _ = populated
        n = (QueryBuilder(store).nodes(NodeType.CALC_FUNCTION)
             .with_state("finished").with_exit_status(0).count())
        assert n == 2

    def test_with_label(self, populated):
        store, pks = populated
        rows = QueryBuilder(store).with_label("chain").all()
        assert [r["pk"] for r in rows] == [pks["work"]]

    # -- node_hash column ----------------------------------------------------
    def test_with_hash(self, populated):
        store, _ = populated
        rows = QueryBuilder(store).with_hash("hash-0").all()
        assert len(rows) == 3
        assert all(r["node_hash"] == "hash-0" for r in rows)
        assert QueryBuilder(store).with_hash("hash-1").count() == 2
        assert QueryBuilder(store).with_hash("missing").count() == 0

    def test_with_process_type_and_hash(self, populated):
        store, pks = populated
        row = (QueryBuilder(store).with_process_type("Adder")
               .with_hash("hash-0").with_state("finished")
               .with_exit_status(0).order_by("pk", desc=True).first())
        assert row["pk"] == pks["calc0"]

    def test_hash_column_survives_roundtrip(self, tmp_path):
        path = str(tmp_path / "qb.db")
        store = ProvenanceStore(path)
        pk = store.create_process_node(NodeType.CALC_JOB, "Job",
                                       node_hash="abc123")
        store.close()
        reopened = ProvenanceStore(path)
        assert reopened.get_node(pk)["node_hash"] == "abc123"
        assert QueryBuilder(reopened).with_hash("abc123").count() == 1

    def test_set_node_hash_and_invalidation_query(self, populated):
        store, pks = populated
        store.set_node_hash(pks["calc0"], None)
        assert QueryBuilder(store).with_hash("hash-0").count() == 2
        store.set_node_hash(pks["calc3"], "rehashed")
        assert QueryBuilder(store).with_hash("rehashed").count() == 1


def test_migration_adds_node_hash_to_legacy_db(tmp_path):
    """A database created before the caching subsystem gains the column
    (and index) on open."""
    import sqlite3

    path = str(tmp_path / "legacy.db")
    conn = sqlite3.connect(path)
    conn.executescript("""
        CREATE TABLE nodes (
            pk INTEGER PRIMARY KEY AUTOINCREMENT,
            uuid TEXT UNIQUE NOT NULL,
            node_type TEXT NOT NULL,
            process_type TEXT,
            label TEXT DEFAULT '',
            description TEXT DEFAULT '',
            attributes TEXT DEFAULT '{}',
            payload TEXT,
            process_state TEXT,
            exit_status INTEGER,
            exit_message TEXT,
            checkpoint TEXT,
            ctime REAL NOT NULL,
            mtime REAL NOT NULL
        );
        INSERT INTO nodes (uuid, node_type, process_type, process_state,
                           ctime, mtime)
        VALUES ('u-1', 'process.calcjob', 'OldJob', 'finished', 1.0, 1.0);
    """)
    conn.commit()
    conn.close()

    store = ProvenanceStore(path)
    node = store.get_node(1)
    assert node["node_hash"] is None           # legacy rows: no fingerprint
    store.set_node_hash(1, "backfilled")
    assert QueryBuilder(store).with_hash("backfilled").count() == 1
    indexes = {r[1] for r in
               store._conn().execute("PRAGMA index_list(nodes)")}
    assert "idx_nodes_hash" in indexes
