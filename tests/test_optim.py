"""Optimizer unit tests: AdamW against hand-computed reference math,
Adafactor state shapes/factored memory, LR schedule properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st  # noqa: E501

from repro.training import optim as O


def test_adamw_matches_reference_math():
    cfg = O.OptimConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                        warmup_steps=1, total_steps=10**9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    state = O.adamw_init(params)
    new_p, new_s, lr = O.adamw_update(cfg, grads, state, params,
                                      jnp.asarray(0))
    # by hand: mu=0.05, nu=0.0025*... => mu_hat=g, nu_hat=g^2 at t=1
    # delta = g / (|g| + eps) = sign(g); p' = p - lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_s["mu"]["w"]),
                               [0.05, 0.05], atol=1e-7)


def test_adamw_weight_decay_decoupled():
    cfg = O.OptimConfig(lr=0.1, weight_decay=0.5, warmup_steps=1,
                        total_steps=10**9)
    params = {"w": jnp.asarray([2.0])}
    grads = {"w": jnp.asarray([0.0])}
    state = O.adamw_init(params)
    new_p, _, _ = O.adamw_update(cfg, grads, state, params, jnp.asarray(0))
    # zero grad: update = lr * wd * p
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [2.0 - 0.1 * 0.5 * 2.0], atol=1e-6)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((128,))}
    state = O.adafactor_init(params)
    assert state["fac"]["w"]["vr"].shape == (64,)
    assert state["fac"]["w"]["vc"].shape == (128,)
    assert state["fac"]["b"]["v"].shape == (128,)
    # memory: 64+128 << 64*128 (the point of adafactor)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_state < n_params / 10


def test_adafactor_reduces_loss():
    cfg = O.OptimConfig(name="adafactor", lr=0.05, warmup_steps=1,
                        total_steps=1000)
    w = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                          jnp.float32)}
    state = O.opt_init(cfg, w)
    target = jnp.eye(8)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(w))
    for step in range(60):
        g = jax.grad(loss)(w)
        w, state, _ = O.opt_update(cfg, g, state, w, jnp.asarray(step))
    assert float(loss(w)) < l0 * 0.3


def test_grad_clip():
    tree = {"a": jnp.asarray([3.0, 4.0])}       # norm 5
    clipped, norm = O.clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               atol=1e-6)
    # under the limit -> unchanged
    clipped2, _ = O.clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0, 4.0])


@given(st.integers(min_value=1, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_lr_schedule_properties(step):
    cfg = O.OptimConfig(lr=1e-3, warmup_steps=100, total_steps=1000,
                        min_lr_ratio=0.1)
    lr = float(O.lr_schedule(cfg, jnp.asarray(step)))
    assert 0.0 < lr <= cfg.lr * (1.0 + 1e-6)
    # floor: never below min_lr_ratio once warm
    if step >= cfg.warmup_steps:
        assert lr >= cfg.lr * cfg.min_lr_ratio * 0.99


def test_lr_schedule_monotone_warmup():
    cfg = O.OptimConfig(lr=1e-3, warmup_steps=50, total_steps=1000)
    lrs = [float(O.lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 49)]
    assert all(b > a for a, b in zip(lrs, lrs[1:]))
    assert lrs[0] > 0.0     # first step must not be a no-op (regression)
