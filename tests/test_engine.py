"""Engine machinery: backoff, transport queue, job manager bundling,
communicator task-queue semantics (paper §II.B.4, §III.C)."""

import asyncio

import pytest

from repro.engine.backoff import TransportTaskExhausted, \
    exponential_backoff_retry
from repro.engine.communicator import LocalCommunicator
from repro.engine.jobmanager import JobManager
from repro.engine.transport import (
    FlakyTransport, LocalTransport, TransportQueue,
)
from repro.calcjobs.scheduler import SimScheduler, SimulatedCluster


def run(coro):
    loop = asyncio.new_event_loop()
    try:
        return loop.run_until_complete(coro)
    finally:
        loop.close()


# ---------------------------------------------------------------------------
# exponential backoff
# ---------------------------------------------------------------------------

def test_backoff_retries_until_success():
    attempts = []
    sleeps = []

    async def flaky():
        attempts.append(1)
        if len(attempts) < 4:
            raise ConnectionError("nope")
        return "ok"

    async def fake_sleep(dt):
        sleeps.append(dt)

    result = run(exponential_backoff_retry(
        flaky, initial_interval=0.1, max_attempts=5, sleeper=fake_sleep,
        jitter=False))
    assert result == "ok"
    assert len(attempts) == 4
    # intervals double: 0.1, 0.2, 0.4
    assert sleeps == [0.1, 0.2, pytest.approx(0.4)]


def test_backoff_full_jitter_bounded_and_counted():
    import random

    from repro.observability import metrics as _metrics

    registry = _metrics.reset_registry()
    sleeps = []

    async def always_fails():
        raise ConnectionError("nope")

    async def fake_sleep(dt):
        sleeps.append(dt)

    with pytest.raises(TransportTaskExhausted):
        run(exponential_backoff_retry(
            always_fails, initial_interval=0.1, max_attempts=4,
            sleeper=fake_sleep, rng=random.Random(7)))
    # full jitter: each wait is uniform in [0, ceiling] with the ceiling
    # doubling per retry — never above it, and (seeded) not exactly at it
    assert len(sleeps) == 3
    for dt, ceiling in zip(sleeps, [0.1, 0.2, 0.4]):
        assert 0.0 <= dt <= ceiling
    assert registry.counter("backoff.retries").value == 3
    assert registry.counter("backoff.exhausted").value == 1


def test_backoff_exhaustion_raises():
    async def always_fails():
        raise TimeoutError("down")

    async def fake_sleep(dt):
        pass

    with pytest.raises(TransportTaskExhausted) as exc:
        run(exponential_backoff_retry(always_fails, max_attempts=3,
                                      sleeper=fake_sleep, name="upload"))
    assert exc.value.attempts == 3
    assert "upload" in str(exc.value)


def test_backoff_non_retryable_propagates():
    async def fails():
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        run(exponential_backoff_retry(fails, non_retryable=(ValueError,)))


# ---------------------------------------------------------------------------
# transport queue (paper §II.B.4.b)
# ---------------------------------------------------------------------------

def test_transport_queue_bundles_connections():
    """N concurrent requests share O(1) connection opens."""

    async def main():
        tq = TransportQueue(safe_interval=0.01)
        t = LocalTransport("hpc")
        tq.register_transport(t)

        async def use():
            tr = await tq.request_transport("hpc")
            assert tr.is_open
            return tr

        await asyncio.gather(*[use() for _ in range(50)])
        return t.open_count, tq.stats

    opens, stats = run(main())
    assert stats["requests"] == 50
    assert opens == 1          # one connection served all 50 requests


def test_transport_queue_safe_interval_enforced():
    import time

    async def main():
        tq = TransportQueue(safe_interval=0.05)
        t = LocalTransport("hpc")
        tq.register_transport(t)
        tr = await tq.request_transport("hpc")
        await tr.close()
        t0 = time.monotonic()
        tr = await tq.request_transport("hpc")   # must wait out the interval
        return time.monotonic() - t0

    elapsed = run(main())
    assert elapsed >= 0.04


# ---------------------------------------------------------------------------
# job manager bundling (paper §II.B.4.c)
# ---------------------------------------------------------------------------

def test_job_manager_bundles_scheduler_queries():
    cluster = SimulatedCluster(queue_delay=0.0, runtime=10.0)

    async def main():
        tq = TransportQueue(safe_interval=0.0)
        tq.register_transport(cluster.make_transport("hpc"))
        manager = JobManager(tq, SimScheduler(), "hpc", flush_interval=0.02)
        # submit 20 jobs directly
        t = await tq.request_transport("hpc")
        job_ids = []
        for i in range(20):
            t.files[f"s{i}.job"] = b"{}"
            rc, out, _ = await t.exec_command(f"sbatch s{i}.job")
            job_ids.append(out.rsplit(" ", 1)[-1])
        # 20 concurrent status requests -> ONE squeue
        before = cluster.stats["queries"]
        states = await asyncio.gather(
            *[manager.request_job_state(j) for j in job_ids])
        return cluster.stats["queries"] - before, states

    queries, states = run(main())
    assert queries == 1
    assert all(s in ("PENDING", "RUNNING") for s in states)


# ---------------------------------------------------------------------------
# communicator task queue: ack on success, requeue on failure
# ---------------------------------------------------------------------------

def test_task_queue_requeues_failed_tasks():
    async def main():
        comm = LocalCommunicator()
        seen = []

        async def handler(payload):
            seen.append(payload["n"])
            if len(seen) == 1:
                raise RuntimeError("first delivery fails")

        comm.add_task_subscriber("q", handler)
        comm.task_send("q", {"n": 7})
        for _ in range(100):
            await asyncio.sleep(0.01)
            if len(seen) >= 2:
                break
        comm.close()
        return seen

    seen = run(main())
    assert seen == [7, 7]     # redelivered after the nack


def test_requeue_timeout_redelivers_hung_task():
    """Visibility-timeout enforcement: a handler that never acks gets its
    task redelivered after requeue_timeout (at-least-once semantics)."""

    async def main():
        comm = LocalCommunicator(requeue_timeout=0.2)
        seen = []
        hung = asyncio.Event()

        async def handler(payload):
            seen.append(payload["n"])
            if len(seen) == 1:
                await hung.wait()      # first delivery hangs forever

        comm.add_task_subscriber("q", handler)
        comm.task_send("q", {"n": 3})
        for _ in range(200):
            await asyncio.sleep(0.02)
            if len(seen) >= 2:
                break
        hung.set()
        comm.close()
        return seen

    seen = run(main())
    assert seen == [3, 3]      # redelivered after the visibility timeout


def test_no_subscriber_task_is_parked_not_spun():
    """A task sent before any subscriber exists waits in the queue (no
    busy-requeue) and is delivered once someone subscribes."""

    async def main():
        comm = LocalCommunicator()
        comm.task_send("q", {"n": 1})
        await asyncio.sleep(0.1)
        assert comm.queue_depth("q") == 1     # still parked, not churned
        seen = []

        async def handler(payload):
            seen.append(payload["n"])

        comm.add_task_subscriber("q", handler)
        for _ in range(100):
            await asyncio.sleep(0.01)
            if seen:
                break
        comm.close()
        return seen

    assert run(main()) == [1]


def test_rpc_identifier_directory():
    async def main():
        comm = LocalCommunicator()
        comm.add_rpc_subscriber("process.1", lambda m: None)
        comm.add_rpc_subscriber("process.2", lambda m: None)
        comm.add_rpc_subscriber("worker.a", lambda m: None)
        idents = comm.rpc_identifiers("process.*")
        comm.close()
        return idents

    assert run(main()) == ["process.1", "process.2"]


def test_broadcast_subject_filter():
    async def main():
        comm = LocalCommunicator()
        got = []
        comm.add_broadcast_subscriber(
            lambda s, sender, b: got.append(s),
            subject_filter="state_changed.*")
        comm.broadcast_send("state_changed.running.finished", 1, {})
        comm.broadcast_send("unrelated.subject", 1, {})
        comm.close()
        return got

    got = run(main())
    assert got == ["state_changed.running.finished"]


def test_rpc_roundtrip():
    async def main():
        comm = LocalCommunicator()
        comm.add_rpc_subscriber("process.1", lambda msg: msg["x"] * 2)
        res = comm.rpc_send("process.1", {"x": 21})
        with pytest.raises(KeyError):
            comm.rpc_send("process.404", {})
        comm.close()
        return res

    assert run(main()) == 42


# ---------------------------------------------------------------------------
# flaky transport + full CalcJob integration is in test_calcjob.py
# ---------------------------------------------------------------------------

def test_flaky_transport_fails_then_recovers():
    async def main():
        t = FlakyTransport(fail_first=2)
        await t.open()
        with pytest.raises(ConnectionError):
            await t.put_file("a", b"1")
        with pytest.raises(ConnectionError):
            await t.put_file("a", b"1")
        await t.put_file("a", b"1")
        # failure budget is per operation kind
        with pytest.raises(ConnectionError):
            await t.get_file("a")
        with pytest.raises(ConnectionError):
            await t.get_file("a")
        assert await t.get_file("a") == b"1"

    run(main())
