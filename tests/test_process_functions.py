"""calcfunction / workfunction provenance (paper figs. 1–2)."""

import pytest

from repro.core import ExitCode, Int, calcfunction, workfunction
from repro.provenance.store import LinkType, NodeType, QueryBuilder


@calcfunction
def add(a, b):
    return a + b


@calcfunction
def multiply(a, b):
    return a * b


@workfunction
def add_multiply(x, y, z):
    return multiply(add(x, y), z)


def test_calcfunction_result_and_provenance(store, runner):
    res = multiply(add(Int(3), Int(4)), Int(5))
    assert res.value == 35
    assert QueryBuilder(store).nodes(NodeType.CALC_FUNCTION).count() == 2
    # fig 1: each calc has 2 inputs and 1 created output
    for node in QueryBuilder(store).nodes(NodeType.CALC_FUNCTION).all():
        ins = store.incoming(node["pk"], LinkType.INPUT_CALC)
        outs = store.outgoing(node["pk"], LinkType.CREATE)
        assert len(ins) == 2 and len(outs) == 1


def test_workfunction_call_links(store, runner):
    res = add_multiply(Int(1), Int(2), Int(3))
    assert res.value == 9
    wf = QueryBuilder(store).nodes(NodeType.WORK_FUNCTION).first()
    calls = store.outgoing(wf["pk"], LinkType.CALL_CALC)
    assert len(calls) == 2                       # fig 2: two CALL links
    rets = store.outgoing(wf["pk"], LinkType.RETURN)
    assert len(rets) == 1
    # the RETURN target is the same node CREATEd by the inner multiply —
    # workfunctions return existing data, they do not create copies
    ret_pk = rets[0][0]
    created_by = store.incoming(ret_pk, LinkType.CREATE)
    assert len(created_by) == 1


def test_exceptions_mark_node_excepted(store, runner):
    @calcfunction
    def boom(a):
        raise RuntimeError("bang")

    with pytest.raises(RuntimeError, match="bang"):
        boom(Int(1))
    node = QueryBuilder(store).nodes(NodeType.CALC_FUNCTION) \
        .with_state("excepted").first()
    assert node is not None
    logs = store.get_logs(node["pk"])
    assert any("bang" in l["message"] for l in logs)


def test_exit_code_return(store, runner):
    @calcfunction
    def refuses(a):
        return ExitCode(410, "nope", "ERROR_NOPE")

    out = refuses(Int(1))
    assert isinstance(out, ExitCode)
    node = QueryBuilder(store).nodes(NodeType.CALC_FUNCTION).first()
    assert node["exit_status"] == 410


def test_dict_outputs(store, runner):
    @calcfunction
    def split(a):
        return {"half": Int(a.value // 2), "rest": Int(a.value % 2)}

    out = split(Int(7))
    assert out["half"].value == 3 and out["rest"].value == 1
    node = QueryBuilder(store).nodes(NodeType.CALC_FUNCTION).first()
    outs = store.outgoing(node["pk"], LinkType.CREATE)
    assert {label for _, _, label in outs} == {"half", "rest"}


def test_nested_workfunctions_nest_call_links(store, runner):
    @workfunction
    def outer(x):
        return add_multiply(x, Int(1), Int(2))

    res = outer(Int(5))
    assert res.value == 12
    wfs = QueryBuilder(store).nodes(NodeType.WORK_FUNCTION).all()
    assert len(wfs) == 2
    outer_node = next(n for n in wfs if n["process_type"] == "outer")
    calls = store.outgoing(outer_node["pk"], LinkType.CALL_WORK)
    assert len(calls) == 1


def test_run_get_node(store, runner):
    result, proc, exit_code = add.run_get_node(Int(2), Int(3))
    assert result.value == 5
    assert exit_code.status == 0
    assert store.get_node(proc.pk)["process_state"] == "finished"
