"""The unified launchers (engine/launch.py): run / run_get_node /
run_get_pk / submit over classes and builders (ISSUE 3 tentpole)."""

import pytest

from repro.core import Int, Process, ProcessState, WorkChain
from repro.engine.launch import (
    instantiate, run, run_get_node, run_get_pk, submit,
)
from repro.provenance.store import NodeType


class AddChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("a", valid_type=Int, serializer=Int)
        spec.input("b", valid_type=Int, serializer=Int)
        spec.output("sum", valid_type=Int)
        spec.outline(cls.go)

    def go(self):
        self.out("sum", Int(self.inputs["a"].value + self.inputs["b"].value))


def test_run_returns_outputs(store, runner):
    results = run(AddChain, a=Int(1), b=Int(2))
    assert results["sum"].value == 3


def test_run_serializes_raw_kwargs(store, runner):
    results = run(AddChain, a=1, b=2)
    assert results["sum"].value == 3


def test_run_get_node_returns_named_tuple(store, runner):
    out = run_get_node(AddChain, a=1, b=41)
    assert out.results["sum"].value == 42
    assert out.node.is_finished_ok
    # tuple unpacking works too
    results, node = out
    assert results is out.results and node is out.node


def test_run_get_pk(store, runner):
    results, pk = run_get_pk(AddChain, a=2, b=3)
    assert results["sum"].value == 5
    node = store.get_node(pk)
    assert node["process_state"] == "finished"


def test_run_accepts_builder_with_overrides(store, runner):
    b = AddChain.get_builder()
    b.a = 10
    b.b = 1
    # keyword arguments override builder values at launch time
    results = run(b, b=Int(20))
    assert results["sum"].value == 30


def test_override_semantics_identical_for_dict_and_kwargs(store, runner):
    """run(builder, {'x': v}) and run(builder, x=v) must produce the same
    merged inputs — both flow through the same builder-merge path."""
    class NestedChain(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.inputs.create_namespace("cfg")
            spec.input("cfg.a", valid_type=Int, serializer=Int)
            spec.input("cfg.b", valid_type=Int, serializer=Int)
            spec.output("sum", valid_type=Int)
            spec.outline(cls.go)

        def go(self):
            self.out("sum", Int(self.inputs["cfg"]["a"].value +
                                self.inputs["cfg"]["b"].value))

    b1 = NestedChain.get_builder()
    b1.cfg = {"a": 1, "b": 2}
    r1 = run(b1, {"cfg": {"b": 40}})     # positional-dict override
    b2 = NestedChain.get_builder()
    b2.cfg = {"a": 1, "b": 2}
    r2 = run(b2, cfg={"b": 40})          # kwargs override
    assert r1["sum"].value == r2["sum"].value == 41


def test_submit_returns_waitable_handle(store, runner):
    handle = submit(AddChain, a=1, b=1)
    assert handle.pk > 0
    node = runner.run_until_complete(runner.wait(handle))
    assert node["process_state"] == "finished"
    assert node["exit_status"] == 0


def test_submit_builder(store, runner):
    b = AddChain.get_builder()
    b.a = 5
    b.b = 6
    handle = submit(b)
    node = runner.run_until_complete(runner.wait(handle))
    assert node["exit_status"] == 0


def test_invalid_inputs_fail_at_launch_with_path(store, runner):
    with pytest.raises(ValueError, match="'inputs.a'"):
        run(AddChain, b=Int(1))


def test_launcher_rejects_non_process(store, runner):
    with pytest.raises(TypeError, match="Process class or a ProcessBuilder"):
        run("not-a-process")


def test_instantiate_creates_node_without_running(store, runner):
    proc = instantiate(AddChain, a=1, b=2)
    assert isinstance(proc, Process)
    assert proc.state is ProcessState.CREATED
    node = store.get_node(proc.pk)
    assert node["process_state"] == "created"
    assert store.load_checkpoint(proc.pk) is not None


def test_explicit_runner_is_honoured(store):
    from repro.engine.runner import Runner

    r = Runner(store=store)
    results, node = run_get_node(AddChain, a=3, b=4, runner=r)
    assert node.runner is r
    assert results["sum"].value == 7
