"""MoE layer invariants: routing correctness, capacity behaviour, gradient
hygiene (stop-gradient through one-hots), EP vs TP strategy equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import mlp as M
from repro.models.common import init_params, spec_shapes


def _setup(moe_sharding="expert", capacity_factor=4.0):
    cfg = reduced_config("moonshot-v1-16b-a3b").replace(
        dtype="float32", param_dtype="float32", moe_sharding=moe_sharding,
        moe_capacity_factor=capacity_factor)
    specs = M.make_moe_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs, jnp.float32)
    return cfg, params


def test_moe_forward_shape_and_finite():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = M.moe_forward(cfg, params, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0        # load-balance loss positive


def test_moe_strategies_numerically_identical():
    """EP vs TP-in-expert is a sharding choice, not a math choice."""
    cfg_ep, params = _setup("expert")
    cfg_tp = cfg_ep.replace(moe_sharding="ffn")
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg_ep.d_model))
    out_ep, aux_ep = M.moe_forward(cfg_ep, params, x)
    out_tp, aux_tp = M.moe_forward(cfg_tp, params, x)
    np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_tp),
                               atol=1e-6)
    assert float(aux_ep) == float(aux_tp)


def test_moe_token_permutation_equivariance():
    """Permuting tokens permutes outputs (at ample capacity)."""
    cfg, params = _setup(capacity_factor=8.0)
    cfg = cfg.replace(moe_group_size=32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, cfg.d_model))
    out, _ = M.moe_forward(cfg, params, x)
    perm = np.random.default_rng(0).permutation(32)
    out_p, _ = M.moe_forward(cfg, params, x[:, perm])
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[:, perm],
                               atol=1e-5)


def test_moe_capacity_drops_overflow_tokens():
    """With capacity 0, every token is dropped -> output is zero."""
    cfg, params = _setup(capacity_factor=1e-9)   # cap floors at 4 slots
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, cfg.d_model))
    out_low, _ = M.moe_forward(cfg, params, x)
    cfg_hi = cfg.replace(moe_capacity_factor=8.0)
    out_hi, _ = M.moe_forward(cfg_hi, params, x)
    # low capacity drops most tokens: far smaller output norm
    assert float(jnp.linalg.norm(out_low)) < \
        0.8 * float(jnp.linalg.norm(out_hi))


def test_moe_router_gradient_flows_but_onehots_blocked():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))

    def loss(p):
        out, aux = M.moe_forward(cfg, p, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    router_g = float(jnp.sum(jnp.abs(g["router"])))
    expert_g = float(jnp.sum(jnp.abs(g["w_gate"])))
    assert router_g > 0.0, "router must learn through topw + aux loss"
    assert expert_g > 0.0
    assert np.isfinite(router_g) and np.isfinite(expert_g)
