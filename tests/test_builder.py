"""ProcessBuilder: namespace-mirroring attribute access, per-assignment
validation, serializer wrapping, dotted get/set, _merge, pruning, exposed
namespaces, and a daemon round-trip (ISSUE 3 tentpole)."""

import time

import pytest

from repro.core import (
    Bool, Dict, Int, PortValidationError, ProcessBuilder, Str, ToContext,
    WorkChain,
)
from repro.core.builder import expand_launch_target
from repro.provenance.store import LinkType


class SubChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.input("n", valid_type=Int, serializer=Int,
                   help="how many units to process")
        spec.input("tag", valid_type=Str, serializer=Str, required=False)
        spec.output("doubled", valid_type=Int)
        spec.outline(cls.go)

    def go(self):
        self.out("doubled", Int(self.inputs["n"].value * 2))


class TopChain(WorkChain):
    @classmethod
    def define(cls, spec):
        super().define(spec)
        spec.expose_inputs(SubChain, namespace="sub")
        spec.input("flag", valid_type=Bool, serializer=Bool,
                   default=lambda: Bool(False))
        spec.output("result", valid_type=Int)
        spec.outline(cls.launch, cls.collect)

    def launch(self):
        return ToContext(child=self.submit(
            SubChain, **self.exposed_inputs(SubChain, "sub")))

    def collect(self):
        self.out("result", self.ctx.child.outputs["doubled"])


# ---------------------------------------------------------------------------
# construction and attribute access
# ---------------------------------------------------------------------------

def test_get_builder_mirrors_port_tree():
    b = TopChain.get_builder()
    assert isinstance(b, ProcessBuilder)
    assert b.process_class is TopChain
    # nested namespaces pre-exist as sub-builders; leaves are unset
    assert "sub" in dir(b)
    assert "n" in dir(b.sub)
    with pytest.raises(AttributeError):
        b.sub.n  # unset leaf

    b.sub.n = Int(3)
    assert b.sub.n.value == 3


def test_builder_doc_carries_help_text():
    b = TopChain.get_builder()
    assert "flag" in b.__doc__
    assert "how many units to process" in b.sub.__doc__
    assert "sub" in repr(b) or "ProcessBuilder" in repr(b)


def test_unknown_port_rejected_at_assignment():
    b = TopChain.get_builder()
    with pytest.raises(AttributeError, match="not a declared input port"):
        b.bogus = 1
    with pytest.raises(AttributeError, match="sub.bogus"):
        b.sub.bogus = 1


def test_type_rejected_at_assignment_with_path():
    b = TopChain.get_builder()
    with pytest.raises(PortValidationError, match="sub.n"):
        b.sub.n = Str("nope")   # Str is a DataValue: serializer skipped,
                                # valid_type check fails with the full path


def test_serializer_wraps_raw_python_on_assignment():
    b = TopChain.get_builder()
    b.sub.n = 3
    assert isinstance(b.sub.n, Int) and b.sub.n.value == 3
    b.flag = True
    assert isinstance(b.flag, Bool)
    with pytest.raises(PortValidationError, match="sub.n"):
        b.sub.n = "not-a-number"


def test_dotted_path_get_set():
    b = TopChain.get_builder()
    b["sub.n"] = 5
    assert b["sub.n"].value == 5
    assert b.sub.n.value == 5


def test_merge_of_nested_dicts():
    b = TopChain.get_builder()
    b._merge({"sub": {"n": 4}, "metadata": {"label": "merged"}})
    assert b.sub.n.value == 4
    assert b.metadata.label == "merged"
    # merge does not clear siblings
    b._merge({"sub": {"tag": "t"}})
    assert b.sub.n.value == 4 and b.sub.tag.value == "t"


def test_namespace_dict_assignment_replaces_contents():
    b = TopChain.get_builder()
    b.sub.n = 1
    b.sub.tag = "old"
    b.sub = {"n": 9}
    assert b.sub.n.value == 9
    with pytest.raises(AttributeError):
        b.sub.tag


def test_namespace_dict_assignment_is_atomic():
    """A failed namespace replacement must leave the previous contents
    untouched — no partial write, no lost values."""
    b = TopChain.get_builder()
    b.sub.n = 1
    b.sub.tag = "keep"
    with pytest.raises(PortValidationError):
        b.sub = {"n": 2, "bogus": 3}    # bogus is undeclared → fails
    assert b.sub.n.value == 1           # old state fully intact
    assert b.sub.tag.value == "keep"


def test_unknown_port_error_catchable_both_ways():
    """Undeclared-port assignment is catchable as the documented
    PortValidationError AND as the pythonic AttributeError, through
    attribute, mapping and _merge entry points alike."""
    b = TopChain.get_builder()
    with pytest.raises(PortValidationError):
        b.bogus = 1
    with pytest.raises(PortValidationError):
        b["bogus"] = 1
    with pytest.raises(PortValidationError):
        b._merge({"bogus": 1})


def test_inputs_prunes_unset_optionals_and_empty_namespaces():
    b = TopChain.get_builder()
    b.sub.n = 2
    inputs = b._inputs(prune=True)
    assert inputs == {"sub": {"n": Int(2)}}
    assert "metadata" not in inputs and "flag" not in inputs
    # unpruned keeps the empty namespaces
    assert "metadata" in b._inputs(prune=False)


def test_dynamic_namespace_accepts_undeclared_keys():
    b = TopChain.get_builder()
    b.metadata.description = "free-form"
    b.metadata.custom_key = {"arbitrary": 1}   # metadata is dynamic
    assert b.metadata.custom_key == {"arbitrary": 1}


def test_expand_launch_target_shapes():
    b = TopChain.get_builder()
    b.sub.n = 3
    cls, inputs = expand_launch_target(b, {"flag": Bool(True)})
    assert cls is TopChain
    assert inputs["sub"]["n"].value == 3 and inputs["flag"].value is True
    cls2, inputs2 = expand_launch_target(TopChain, {"sub": {"n": Int(1)}})
    assert cls2 is TopChain and inputs2["sub"]["n"].value == 1
    with pytest.raises(TypeError):
        expand_launch_target(42)


# ---------------------------------------------------------------------------
# end-to-end: builder → run, provenance, exposed namespaces
# ---------------------------------------------------------------------------

def test_builder_run_end_to_end_with_provenance(store, runner):
    from repro.engine.launch import run_get_node

    b = TopChain.get_builder()
    b.sub.n = 3          # raw int: serialized to Int(3)
    results, node = run_get_node(b)
    assert node.is_finished_ok
    assert results["result"].value == 6
    # the serialized raw int is a real linked input node on the child
    child_pk = store.outgoing(node.pk, LinkType.CALL_WORK)[0][0]
    inputs = {label: store.load_data(pk)
              for pk, _, label in store.incoming(child_pk, LinkType.INPUT_WORK)}
    assert inputs["n"] == Int(3)


def test_exposed_namespace_builder_roundtrip(store, runner):
    """Builder assignment into an exposed namespace reaches the child via
    WorkChain.exposed_inputs — the full expose/builder round-trip."""
    from repro.engine.launch import run_get_node

    b = TopChain.get_builder()
    b.sub.n = 10
    b.sub.tag = "exposed"
    results, node = run_get_node(b)
    assert results["result"].value == 20
    child_pk = store.outgoing(node.pk, LinkType.CALL_WORK)[0][0]
    labels = {label for _, _, label in store.incoming(child_pk)}
    assert {"n", "tag"} <= labels


def test_callable_default_with_serializer_per_instantiation(store, runner):
    class LambdaDefault(WorkChain):
        @classmethod
        def define(cls, spec):
            super().define(spec)
            spec.input("n", valid_type=Int, serializer=Int,
                       default=lambda: 7)
            spec.output("n_out", valid_type=Int)
            spec.outline(cls.go)

        def go(self):
            self.out("n_out", self.inputs["n"])

    p1 = LambdaDefault(inputs={}, runner=runner)
    p2 = LambdaDefault(inputs={}, runner=runner)
    # each instantiation evaluates the lambda and serializes it freshly
    assert isinstance(p1.inputs["n"], Int) and p1.inputs["n"].value == 7
    assert p1.inputs["n"] is not p2.inputs["n"]


def test_construction_serializes_raw_dict_inputs(store, runner):
    """The serializer contract holds for plain-dict launches too — the
    construction path serializes before validating."""
    outputs, proc = runner.run(SubChain, {"n": 21})
    assert proc.is_finished_ok
    assert outputs["doubled"].value == 42


def test_builder_submit_local(store, runner):
    from repro.engine.launch import submit

    b = SubChain.get_builder()
    b.n = 4
    handle = submit(b)
    node = runner.run_until_complete(runner.wait(handle))
    assert node["process_state"] == "finished"
    assert node["exit_status"] == 0


# ---------------------------------------------------------------------------
# daemon round-trip: builder-built inputs survive the durable task queue
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_builder_roundtrip_through_daemon(tmp_path):
    from repro.calcjobs import TPUTrainJob
    from repro.engine.daemon import Daemon
    from repro.provenance.store import configure_store

    daemon = Daemon(str(tmp_path), workers=1, slots=4)
    daemon.start()
    try:
        store = configure_store(daemon.store_path)
        b = TPUTrainJob.get_builder()
        b.config = Dict({"arch": "qwen2-0.5b", "steps": 1, "batch": 1,
                         "seq": 8, "seed": 3})
        b.metadata.label = "builder-daemon-job"
        pk = daemon.submit(b)

        t0 = time.time()
        while time.time() - t0 < 150:
            node = store.get_node(pk)
            if node and node["process_state"] in ("finished", "excepted",
                                                  "killed"):
                break
            daemon.supervise()
            time.sleep(0.3)
        else:
            raise TimeoutError(f"process {pk} did not finish")
        assert node["process_state"] == "finished"
        assert node["exit_status"] == 0
        assert node["label"] == "builder-daemon-job"
        labels = {label for _, _, label in store.incoming(pk)}
        assert "config" in labels
    finally:
        daemon.stop()
