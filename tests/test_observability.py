"""Observability layer: span tracer, metrics registry, persisted process
timelines, namespaced logging, and the stats/top CLI surface (ISSUE 6)."""

import asyncio
import json
import logging
import time

import pytest

from repro import cli
from repro.core import Int, calcfunction
from repro.observability import logs as obs_logs
from repro.observability import metrics, trace
from repro.observability.timeline import (
    TRACE_LEVELNAME, load_spans, render_timeline, serialize_spans,
    state_dwell,
)


@pytest.fixture(autouse=True)
def _obs_reset():
    """Isolate tracer + registry global state per test."""
    trace.reset()
    metrics.reset_registry()
    yield
    trace.reset()
    metrics.reset_registry()


@pytest.fixture()
def _clean_repro_logger():
    logger = logging.getLogger("repro")
    saved = (list(logger.handlers), logger.level, logger.propagate)
    for h in list(logger.handlers):
        logger.removeHandler(h)
    yield logger
    for h in list(logger.handlers):
        logger.removeHandler(h)
    logger.handlers[:] = saved[0]
    logger.setLevel(saved[1])
    logger.propagate = saved[2]


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------

class TestSpans:
    def test_nesting_records_parent_ids(self):
        trace.enable()
        with trace.capture() as tl:
            with trace.span("outer") as outer:
                with trace.span("inner") as inner:
                    pass
        assert inner.parent_id == outer.span_id
        assert outer.parent_id is None
        names = [s.name for s in tl.spans]
        assert names == ["inner", "outer"]  # finish order
        assert all(s.end >= s.start for s in tl.spans)

    def test_contextvar_propagation_across_async_tasks(self):
        trace.enable()
        parent_of_task_span = {}

        async def child():
            with trace.span("in_task") as s:
                await asyncio.sleep(0)
            parent_of_task_span["id"] = s.parent_id

        async def main():
            with trace.span("root") as root:
                # tasks inherit the context of their creation point
                task = asyncio.ensure_future(child())
                await task
            return root.span_id

        with trace.capture():
            root_id = asyncio.new_event_loop().run_until_complete(main())
        assert parent_of_task_span["id"] == root_id

    def test_disabled_fast_path_returns_shared_singleton(self):
        trace.disable()
        a = trace.span("a")
        b = trace.span("b", pk=42)
        assert a is b  # the no-op singleton: no allocation per call
        with trace.capture() as tl:
            with trace.span("x"):
                pass
        assert tl.spans == []
        assert trace.start_timeline() is None

    def test_traced_decorator_sync_and_async(self):
        trace.enable()

        @trace.traced("named")
        def f(x):
            return x + 1

        @trace.traced()
        async def g(x):
            return x * 2

        with trace.capture() as tl:
            assert f(1) == 2
            assert asyncio.new_event_loop().run_until_complete(g(3)) == 6
        assert [s.name for s in tl.spans][0] == "named"
        assert len(tl.spans) == 2

    def test_timeline_drain_stamps_open_spans_and_closes(self):
        trace.enable()
        tl = trace.start_timeline()
        token = trace.push_sink(tl)
        try:
            root = trace.span("root")
            root.__enter__()
            with trace.span("done"):
                pass
            drained = tl.drain(stamp_open=True)
        finally:
            root.__exit__(None, None, None)
            trace.pop_sink(token)
        names = {s["name"] for s in drained}
        assert names == {"root", "done"}
        root_dict = next(s for s in drained if s["name"] == "root")
        assert root_dict["end"] >= root_dict["start"]
        # root exited after the drain: its append was dropped (closed
        # timeline), so a re-drain sees only the originally recorded span
        assert [s["name"] for s in tl.drain()] == ["done"]

    def test_sampling_keeps_fraction_of_root_spans(self):
        trace.enable(sample=0.0)
        assert trace.span("root") is not None
        with trace.capture() as tl:
            with trace.span("root"):
                pass
        assert tl.spans == []
        assert trace.start_timeline() is None
        trace.enable(sample=1.0)
        assert trace.start_timeline() is not None


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter_gauge_histogram_semantics(self):
        reg = metrics.MetricsRegistry()
        reg.counter("c").inc()
        reg.counter("c").inc(4)
        reg.gauge("g").set(2.0)
        reg.gauge("g").dec()
        h = reg.histogram("h")
        for v in (0.0005, 0.02, 100.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["counters"]["c"] == 5
        assert snap["gauges"]["g"] == 1.0
        assert snap["histograms"]["h"]["count"] == 3
        assert snap["histograms"]["h"]["counts"][0] == 1   # < 1ms
        assert snap["histograms"]["h"]["counts"][-1] == 1  # overflow

    def test_concurrent_asyncio_writers(self):
        reg = metrics.MetricsRegistry()

        async def writer(i):
            for _ in range(100):
                reg.counter("hits").inc()
                reg.histogram("lat").observe(0.001 * i)
                await asyncio.sleep(0)

        async def main():
            await asyncio.gather(*[writer(i) for i in range(10)])

        asyncio.new_event_loop().run_until_complete(main())
        snap = reg.snapshot()
        assert snap["counters"]["hits"] == 1000
        assert snap["histograms"]["lat"]["count"] == 1000

    def test_statsdict_is_backcompat_dict_and_feeds_registry(self):
        reg = metrics.MetricsRegistry()
        stats = metrics.StatsDict("store", {"commits": 0}, registry=reg)
        assert isinstance(stats, dict)
        stats["commits"] += 2         # the legacy hot-path idiom
        assert stats.get("commits") == 2
        other = metrics.StatsDict("store", {"commits": 3}, registry=reg)
        assert other["commits"] == 3
        # snapshot sums instances sharing a prefix
        assert reg.snapshot()["counters"]["store.commits"] == 5

    def test_merge_snapshots_sums_counters_and_histograms(self):
        reg1, reg2 = metrics.MetricsRegistry(), metrics.MetricsRegistry()
        reg1.counter("n").inc(2)
        reg2.counter("n").inc(3)
        reg2.counter("only2").inc()
        reg1.gauge("g").set(1)
        reg2.gauge("g").set(7)
        reg1.histogram("h").observe(0.01)
        reg2.histogram("h").observe(0.02)
        merged = metrics.merge_snapshots(
            [reg1.snapshot(), reg2.snapshot(), None])
        assert merged["counters"] == {"n": 5, "only2": 1}
        assert merged["gauges"]["g"] == 7  # last wins
        assert merged["histograms"]["h"]["count"] == 2


# ---------------------------------------------------------------------------
# Timeline persistence + dwell times
# ---------------------------------------------------------------------------

def _creator_pk(store, result):
    """The calcfunction process node that CREATEd this data node."""
    from repro.provenance.store import LinkType
    return store.incoming(result.pk, LinkType.CREATE)[0][0]


class TestTimelinePersistence:
    def test_calcfunction_persists_timeline_within_commit_budget(
            self, runner, store):
        trace.enable()

        @calcfunction
        def add(a, b):
            return a + b

        add(Int(1), Int(2))          # warm spec/import caches
        commits0 = store.stats["commits"]
        result = add(Int(3), Int(4))
        assert (store.stats["commits"] - commits0) <= 3
        pk = _creator_pk(store, result)
        spans = load_spans(store, pk)
        names = {s["name"] for s in spans}
        assert "process.run" in names
        # the timeline rides the terminal transaction as ONE TRACE row
        trace_rows = [log for log in store.get_logs(pk)
                      if log["levelname"] == TRACE_LEVELNAME]
        assert len(trace_rows) == 1
        rendered = render_timeline(spans)
        assert "process.run" in rendered and "total" in rendered

    def test_untraced_run_stores_no_trace_rows(self, runner, store):
        trace.disable()

        @calcfunction
        def add(a, b):
            return a + b

        result = add(Int(1), Int(2))
        pk = _creator_pk(store, result)
        assert load_spans(store, pk) == []
        assert "no spans recorded" in render_timeline([])

    def test_serialize_normalizes_starts_to_offsets(self):
        doc = serialize_spans([
            {"name": "a", "id": 1, "parent": None,
             "start": 1000.5, "end": 1000.9},
            {"name": "b", "id": 2, "parent": 1,
             "start": 1000.6, "end": 1000.7, "attrs": {"pk": 3}},
        ])
        spans = json.loads(doc)["spans"]
        assert spans[0]["start"] == 0.0
        assert spans[1]["start"] == pytest.approx(0.1)
        assert spans[1]["attrs"] == {"pk": 3}

    def test_state_dwell_from_state_history(self, runner, store):
        @calcfunction
        def add(a, b):
            return a + b

        pk = _creator_pk(store, add(Int(1), Int(2)))
        node = store.get_node(pk)
        rows = dict(state_dwell(node))
        assert "running" in rows and "finished" in rows

    def test_state_dwell_legacy_fallback(self):
        node = {"attributes": "{}", "ctime": 100.0, "mtime": 103.5,
                "process_state": "finished"}
        rows = state_dwell(node)
        assert len(rows) == 1
        assert rows[0][0].startswith("(total")
        assert rows[0][1] == pytest.approx(3.5)


# ---------------------------------------------------------------------------
# Logging configuration
# ---------------------------------------------------------------------------

class TestLogs:
    def test_configure_touches_only_repro_namespace(self, _clean_repro_logger):
        root_handlers = list(logging.getLogger().handlers)
        logger = obs_logs.configure(level="INFO")
        assert logger.name == "repro"
        assert logging.getLogger().handlers == root_handlers
        assert logger.level == logging.INFO
        assert logger.propagate is False

    def test_configure_is_idempotent(self, _clean_repro_logger):
        obs_logs.configure(level="INFO")
        obs_logs.configure(level="DEBUG")
        logger = logging.getLogger("repro")
        ours = [h for h in logger.handlers
                if getattr(h, "_repro_obs", False)]
        assert len(ours) == 1
        assert logger.level == logging.DEBUG

    def test_env_var_sets_level(self, _clean_repro_logger, monkeypatch):
        monkeypatch.setenv(obs_logs.ENV_VAR, "debug")
        assert obs_logs.configure().level == logging.DEBUG
        with pytest.raises(ValueError):
            obs_logs._resolve_level("NOT_A_LEVEL")

    def test_records_carry_worker_and_pk_context(self, _clean_repro_logger):
        import io

        stream = io.StringIO()
        obs_logs.configure(level="INFO", worker_id="worker.1-abc",
                           stream=stream)
        logger = logging.getLogger("repro.test")
        try:
            with obs_logs.pk_context(42):
                logger.info("inside")
            logger.info("outside")
        finally:
            obs_logs.set_worker_id(None)
        out = stream.getvalue()
        assert "[worker.1-abc pk=42]: inside" in out
        assert "[worker.1-abc]: outside" in out


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

@pytest.fixture()
def profile(tmp_path):
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    db = str(tmp_path / "profile.db")
    st = configure_store(db)
    set_default_runner(Runner(store=st))
    trace.enable()

    @calcfunction
    def add(a, b):
        return a + b

    add(Int(1), Int(2))
    trace.disable()
    st.close()
    set_default_runner(None)
    return db


class TestCli:
    def test_stats_json_schema(self, profile, capsys):
        cli.main(["-p", profile, "stats", "--json"])
        doc = json.loads(capsys.readouterr().out)
        assert set(doc) == {"nodes", "unfinished", "metrics", "repository",
                            "workers"}
        assert doc["nodes"].get("process.calcfunction") == 1
        assert doc["unfinished"] == 0
        assert doc["workers"] == []  # no daemon running
        assert "counters" in doc["metrics"]
        assert set(doc["repository"]) == {"blobs", "bytes"}

    def test_stats_plain_lists_counters(self, profile, capsys):
        cli.main(["-p", profile, "stats"])
        out = capsys.readouterr().out
        assert "repository:" in out
        assert "counters:" in out

    def test_report_renders_dwell_and_timeline(self, profile, capsys):
        cli.main(["-p", profile, "process", "report", "1"])
        out = capsys.readouterr().out
        assert "state dwell times:" in out
        assert "running" in out
        assert "span timeline:" in out
        assert "process.run" in out
        # the raw TRACE json row must not leak into the log listing
        assert '"spans"' not in out

    def test_top_once_without_daemon_is_an_answer(self, profile, tmp_path,
                                                  capsys):
        cli.main(["-p", profile, "process", "top", "--once",
                  "-w", str(tmp_path / "nodaemon")])
        out = capsys.readouterr().out
        assert "nothing running" in out


# ---------------------------------------------------------------------------
# Daemon round-trip (spans recorded by a worker OS process, read here)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_daemon_roundtrip_persists_timeline(tmp_path, monkeypatch, capsys):
    from repro.calcjobs import TPUTrainJob
    from repro.core import Dict
    from repro.engine.daemon import Daemon
    from repro.provenance.store import configure_store

    monkeypatch.setenv(trace.ENV_VAR, "1")  # inherited by spawned workers
    daemon = Daemon(str(tmp_path), workers=1, slots=4)
    daemon.start()
    try:
        pk = daemon.submit(TPUTrainJob, {"config": Dict(
            {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8})})
        store = configure_store(daemon.store_path)
        deadline = time.time() + 150
        while time.time() < deadline:
            node = store.get_node(pk)
            if node and node.get("process_state") in ("finished", "excepted",
                                                      "killed"):
                break
            daemon.supervise()
            time.sleep(0.4)
        assert node["process_state"] == "finished", node
        spans = load_spans(store, pk)
        assert spans, "worker did not persist a span timeline"
        assert {"process.run"} <= {s["name"] for s in spans}
        cli.main(["-p", daemon.store_path, "process", "report", str(pk)])
        out = capsys.readouterr().out
        assert "span timeline:" in out and "process.run" in out
    finally:
        daemon.stop()
