"""Daemon integration: durable broker, multi-process workers, crash
recovery (paper §III.A.1 + §III.C.a). Slower than unit tests but the
core fault-tolerance claims live here."""

import sys
import time

import pytest

from repro.calcjobs import TPUTrainJob
from repro.core import Dict
from repro.engine.daemon import Daemon
from repro.provenance.store import configure_store

TERMINAL = ("finished", "excepted", "killed")
SMALL = {"arch": "qwen2-0.5b", "steps": 1, "batch": 1, "seq": 8}


def _wait_all(daemon, store, pks, timeout=150, supervise=True,
              heal_after=None):
    t0 = time.time()
    restarts = 0
    while time.time() - t0 < timeout:
        states = {pk: (store.get_node(pk) or {}).get("process_state")
                  for pk in pks}
        if all(s in TERMINAL for s in states.values()):
            return states, restarts
        if supervise:
            r = daemon.supervise()
            restarts += r
            if heal_after is not None and restarts >= heal_after:
                daemon.crash_after = None
        time.sleep(0.4)
    return states, restarts


@pytest.mark.slow
def test_daemon_processes_jobs(tmp_path):
    daemon = Daemon(str(tmp_path), workers=2, slots=8)
    daemon.start()
    try:
        pks = [daemon.submit(TPUTrainJob,
                             {"config": Dict({**SMALL, "seed": i})})
               for i in range(3)]
        store = configure_store(daemon.store_path)
        states, _ = _wait_all(daemon, store, pks)
        assert all(s == "finished" for s in states.values()), states
        assert all(store.get_node(pk)["exit_status"] == 0 for pk in pks)
    finally:
        daemon.stop()


@pytest.mark.slow
def test_daemon_worker_crash_recovery(tmp_path):
    """Workers hard-exit mid-job; the broker requeues their tasks; the
    supervisor restarts workers; jobs finish from their checkpoints."""
    daemon = Daemon(str(tmp_path), workers=2, slots=8, crash_after=1.5)
    daemon.start()
    try:
        pks = [daemon.submit(TPUTrainJob,
                             {"config": Dict({**SMALL, "seed": i})})
               for i in range(3)]
        store = configure_store(daemon.store_path)
        states, restarts = _wait_all(daemon, store, pks, timeout=200,
                                     heal_after=4)
        assert restarts > 0, "no worker crashes were injected"
        assert all(s == "finished" for s in states.values()), states
        assert all(store.get_node(pk)["exit_status"] == 0 for pk in pks)
    finally:
        daemon.stop()
