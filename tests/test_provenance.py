"""Provenance store: nodes, links, logs, QueryBuilder, graph invariants."""

import pytest
from _hypothesis_compat import given, settings, strategies as st  # noqa: E501

from repro.core import ArrayData, Dict, Float, Int, Str
from repro.core.datatypes import DataValue, FolderData, to_data_value
from repro.provenance.store import (
    LinkType, NodeType, ProvenanceStore, QueryBuilder,
)

import numpy as np


def test_store_and_load_roundtrip(store):
    for value in (Int(7), Float(2.5), Str("hi"), Dict({"a": 1}),
                  ArrayData(np.arange(6).reshape(2, 3))):
        store.store_data(value)
        loaded = store.load_data(value.pk)
        assert loaded == value
        assert loaded.uuid == value.uuid


def test_folder_data_roundtrip(store):
    f = FolderData({"metrics.json": b"{}", "log.txt": b"hello"})
    store.store_data(f)
    loaded = store.load_data(f.pk)
    assert loaded.names() == ["log.txt", "metrics.json"]
    assert loaded.get_bytes("log.txt") == b"hello"


def test_store_is_idempotent(store):
    v = Int(3)
    store.store_data(v)
    pk1 = v.pk
    store.store_data(v)
    assert v.pk == pk1
    assert store.count_nodes(NodeType.DATA) == 1


def test_links_and_traversal(store):
    a, b = Int(1), Int(2)
    store.store_data(a)
    store.store_data(b)
    proc = store.create_process_node(NodeType.CALC_FUNCTION, "add")
    store.add_link(a.pk, proc, LinkType.INPUT_CALC, "x")
    store.add_link(b.pk, proc, LinkType.INPUT_CALC, "y")
    out = Int(3)
    store.store_data(out)
    store.add_link(proc, out.pk, LinkType.CREATE, "result")
    assert {p for p, _, _ in store.incoming(proc)} == {a.pk, b.pk}
    assert [p for p, _, _ in store.outgoing(proc)] == [out.pk]


def test_querybuilder_filters(store):
    for i in range(5):
        pk = store.create_process_node(NodeType.WORK_CHAIN, "WC",
                                       label=f"wc{i}")
        store.update_process(pk, state="finished", exit_status=i % 2)
    qb = QueryBuilder(store).nodes(NodeType.WORK_CHAIN).with_exit_status(0)
    assert qb.count() == 3
    assert QueryBuilder(store).nodes(NodeType.WORK_CHAIN) \
        .with_label("wc3").first()["label"] == "wc3"
    assert QueryBuilder(store).nodes("process").count() == 5


def test_logs(store):
    pk = store.create_process_node(NodeType.WORK_CHAIN, "WC")
    store.add_log(pk, "REPORT", "hello world")
    store.add_log(pk, "ERROR", "boom")
    logs = store.get_logs(pk)
    assert [l["levelname"] for l in logs] == ["REPORT", "ERROR"]


def test_unfinished_processes(store):
    p1 = store.create_process_node(NodeType.CALC_JOB, "J")
    p2 = store.create_process_node(NodeType.CALC_JOB, "J")
    store.update_process(p2, state="finished", exit_status=0)
    unfinished = [n["pk"] for n in store.unfinished_processes()]
    assert p1 in unfinished and p2 not in unfinished


def test_checkpoint_roundtrip(store):
    pk = store.create_process_node(NodeType.WORK_CHAIN, "WC")
    assert store.load_checkpoint(pk) is None
    store.save_checkpoint(pk, {"stage": "submit", "ctx": {"n": 3}})
    assert store.load_checkpoint(pk)["ctx"]["n"] == 3
    store.delete_checkpoint(pk)
    assert store.load_checkpoint(pk) is None


@given(st.one_of(
    st.integers(min_value=-10**9, max_value=10**9),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=40),
    st.booleans(),
    st.lists(st.integers(min_value=0, max_value=100), max_size=10),
))
@settings(max_examples=40, deadline=None)
def test_datavalue_payload_roundtrip_property(value):
    dv = to_data_value(value)
    back = DataValue.from_payload(dv.to_payload())
    assert back == dv


@given(st.integers(min_value=1, max_value=12))
@settings(max_examples=10, deadline=None)
def test_provenance_graph_acyclic_property(n_calls):
    """Chained calcfunction executions form a DAG: no pk is reachable from
    itself following link direction."""
    from repro.core import calcfunction
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    store = configure_store(":memory:")
    set_default_runner(Runner(store=store))

    @calcfunction
    def inc(a):
        return Int(a.value + 1)

    v = Int(0)
    for _ in range(n_calls):
        v = inc(v)
    assert v.value == n_calls

    # BFS over outgoing links from every node; no cycles
    edges = {}
    total = store.count_nodes()
    for pk in range(1, total + 1):
        edges[pk] = [o for o, _, _ in store.outgoing(pk)]
    seen_order = {}

    def dfs(u, stack):
        assert u not in stack, "cycle in provenance graph"
        if u in seen_order:
            return
        seen_order[u] = True
        for w in edges.get(u, []):
            dfs(w, stack | {u})

    for pk in edges:
        dfs(pk, frozenset())
    set_default_runner(None)
