"""Quick CPU smoke: loss + train step + prefill + decode for every reduced
arch config. Not a pytest file — a fast dev loop while building."""

import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import ARCH_IDS, reduced_config
from repro.models.registry import SHAPES, ShapeCell, build
from repro.training.train_step import TrainConfig, init_train_state, \
    make_train_step
from repro.serving.serve import make_decode_step, make_prefill_step


def main():
    rng = jax.random.PRNGKey(0)
    failures = []
    for arch in ARCH_IDS:
        if arch == "aiida-demo-110m":
            continue
        t0 = time.time()
        try:
            cfg = reduced_config(arch)
            bundle = build(cfg)
            params = bundle.init_params(rng)
            b, s = 2, 64
            cell = ShapeCell("smoke", "train", s, b)
            batch_struct = bundle.batch_struct(cell)
            batch = {}
            for k, v in batch_struct.items():
                if v.dtype == jnp.int32:
                    batch[k] = jax.random.randint(rng, v.shape, 0,
                                                  cfg.vocab_size)
                else:
                    batch[k] = jax.random.normal(rng, v.shape, v.dtype)
            loss, metrics = bundle.loss_fn(params, batch)
            assert jnp.isfinite(loss), f"{arch}: loss not finite: {loss}"

            tcfg = TrainConfig()
            state = init_train_state(bundle, tcfg, rng)
            step = jax.jit(make_train_step(bundle, tcfg))
            state, m = step(state, batch)
            assert jnp.isfinite(m["loss"]), f"{arch}: train loss {m['loss']}"

            # serving
            max_len = s + 8
            cache = bundle.init_cache(b, max_len)
            prefill = jax.jit(make_prefill_step(bundle))
            tok, cache = prefill(params, batch, cache)
            assert tok.shape == (b, 1)
            decode = jax.jit(make_decode_step(bundle))
            tok, cache = decode(params, cache, tok, jnp.asarray(s))
            assert tok.shape == (b, 1)
            assert int(tok.min()) >= 0
            print(f"[ok] {arch:24s} loss={float(loss):.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((arch, str(e)))
            print(f"[FAIL] {arch}: {e}", flush=True)
    if failures:
        sys.exit(1)
    print("all smoke ok")


if __name__ == "__main__":
    main()
