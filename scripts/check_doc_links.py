"""Fail when docs contain dead relative links.

Scans markdown files (default: docs/*.md, README.md) for inline
`[text](target)` links, resolves each *relative* target against the
file's directory and exits non-zero listing every target that does not
exist. External (http/https/mailto) links and pure in-page anchors are
skipped; a `path#fragment` target is checked for the path part only.

    python scripts/check_doc_links.py [files...]
"""

from __future__ import annotations

import glob
import os
import re
import sys

# inline links only; reference-style links are not used in this repo.
# [^)\s]+ keeps the match clear of ") " so trailing prose is not swallowed
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: str) -> list[tuple[int, str]]:
    base = os.path.dirname(os.path.abspath(path))
    dead: list[tuple[int, str]] = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            for target in _LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                if target.startswith("#"):
                    continue  # in-page anchor
                rel = target.split("#", 1)[0]
                if not os.path.exists(os.path.join(base, rel)):
                    dead.append((lineno, target))
    return dead


def main(argv: list[str]) -> int:
    files = argv or sorted(glob.glob("docs/*.md")) + \
        [f for f in ("README.md",) if os.path.exists(f)]
    failures = 0
    for path in files:
        for lineno, target in check_file(path):
            print(f"{path}:{lineno}: dead link -> {target}")
            failures += 1
    if failures:
        print(f"\n{failures} dead link(s)")
        return 1
    print(f"checked {len(files)} file(s): all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
