"""Lint the chaos fault-point surface so the three views stay in sync:

1. every name registered in ``repro.chaos.faults.CATALOG`` is actually
   instrumented — a ``fault_point("<name>")`` literal exists in src/repro;
2. every ``fault_point(...)`` call site uses a registered name (no drift
   toward unregistered, untestable seams);
3. every fault clause in the built-in scenarios parses and targets at
   least one registered point (``ChaosPlan.parse`` enforces this);
4. every registered name is documented in docs/chaos.md;
5. every built-in scenario is documented in docs/chaos.md.

    python scripts/check_fault_points.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

_CALL = re.compile(r"""(?:chaos|faults)\.fault_point\(\s*['"]([^'"]+)['"]""")


def main() -> int:
    from repro.chaos.faults import CATALOG, ChaosPlan
    from repro.chaos.harness import SCENARIOS

    errors: list[str] = []

    # 1 + 2: catalog <-> instrumented call sites
    called: dict[str, list[str]] = {}
    src = os.path.join(REPO, "src", "repro")
    for dirpath, _dirs, files in os.walk(src):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            with open(path, encoding="utf-8") as fh:
                for name in _CALL.findall(fh.read()):
                    called.setdefault(name, []).append(
                        os.path.relpath(path, REPO))
    for name in sorted(set(CATALOG) - set(called)):
        errors.append(f"catalog point {name!r} has no fault_point() call "
                      "site in src/repro")
    for name in sorted(set(called) - set(CATALOG)):
        errors.append(f"fault_point({name!r}) in {called[name]} is not "
                      "registered in CATALOG")

    # 3: scenario fault clauses parse and resolve against the catalog
    for sc in SCENARIOS.values():
        if not sc.chaos:
            continue
        try:
            ChaosPlan.parse(f"seed=1;{sc.chaos}")
        except ValueError as exc:
            errors.append(f"scenario {sc.name!r}: bad fault spec: {exc}")

    # 4: the docs cover every point
    docs = os.path.join(REPO, "docs", "chaos.md")
    if not os.path.exists(docs):
        errors.append("docs/chaos.md does not exist")
    else:
        with open(docs, encoding="utf-8") as fh:
            text = fh.read()
        for name in sorted(CATALOG):
            if name not in text:
                errors.append(f"catalog point {name!r} is not documented "
                              "in docs/chaos.md")
        # 5: ... and every built-in scenario
        for name in sorted(SCENARIOS):
            if f"`{name}`" not in text:
                errors.append(f"scenario {name!r} is not documented in "
                              "docs/chaos.md")

    if errors:
        print(f"check_fault_points: {len(errors)} problem(s)")
        for err in errors:
            print(f"  - {err}")
        return 1
    print(f"check_fault_points: OK ({len(CATALOG)} points instrumented, "
          f"{len(SCENARIOS)} scenarios, docs in sync)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
