import sys, time, tempfile
sys.path.insert(0, "src")
from repro.core import Dict
from repro.engine.daemon import Daemon
from repro.provenance.store import configure_store
from repro.calcjobs import TPUTrainJob


def main():
    workdir = tempfile.mkdtemp(prefix="daemon_crash_")
    # workers hard-exit (os._exit(17)) ~1.5s after starting — mid-job
    daemon = Daemon(workdir, workers=2, slots=10, crash_after=1.5)
    daemon.start()

    pks = [daemon.submit(TPUTrainJob, {"config": Dict({
        "arch": "qwen2-0.5b", "steps": 2, "batch": 1, "seq": 16,
        "seed": i})}) for i in range(4)]
    print("submitted", pks)

    store = configure_store(daemon.store_path)
    t0 = time.time()
    restarts = 0
    states = {}
    while time.time() - t0 < 200:
        states = {pk: (store.get_node(pk) or {}).get("process_state")
                  for pk in pks}
        if all(s in ("finished", "excepted", "killed")
               for s in states.values()):
            break
        r = daemon.supervise()
        if r:
            restarts += r
            # after a few crashes let replacements live
            if restarts >= 4:
                daemon.crash_after = None
        time.sleep(0.4)
    print("restarts:", restarts, "states:", states)
    daemon.stop()
    ok = all((store.get_node(pk) or {}).get("exit_status") == 0
             for pk in pks) and restarts > 0
    print("CRASH RECOVERY", "PASS" if ok else "FAIL")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
