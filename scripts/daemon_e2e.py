import sys, time, tempfile
sys.path.insert(0, "src")
from repro.core import Dict
from repro.engine.daemon import Daemon
from repro.provenance.store import configure_store
from repro.calcjobs import TPUTrainJob


def main():
    workdir = tempfile.mkdtemp(prefix="daemon_test_")
    daemon = Daemon(workdir, workers=2, slots=10)
    daemon.start()
    print("daemon started on", daemon.host, daemon.port)

    pks = []
    for i in range(4):
        pk = daemon.submit(TPUTrainJob, {"config": Dict({
            "arch": "qwen2-0.5b", "steps": 2, "batch": 1, "seq": 16,
            "seed": i})})
        pks.append(pk)
    print("submitted", pks)

    store = configure_store(daemon.store_path)
    t0 = time.time()
    states = {}
    while time.time() - t0 < 150:
        states = {pk: (store.get_node(pk) or {}).get("process_state")
                  for pk in pks}
        if all(s in ("finished", "excepted", "killed")
               for s in states.values()):
            break
        daemon.supervise()
        time.sleep(0.5)
    print("final states:", states)
    for pk in pks:
        n = store.get_node(pk)
        print(pk, n["process_state"], "exit:", n["exit_status"])
    daemon.stop()
    ok = all((store.get_node(pk) or {}).get("exit_status") == 0 for pk in pks)
    print("DAEMON E2E", "PASS" if ok else "FAIL")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
