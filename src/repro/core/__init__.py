# The paper's primary contribution: the event-based process engine —
# ProcessSpec/ports, the extended state machine, calcfunction/workfunction
# provenance decorators, and the checkpointable WorkChain outline DSL.

from repro.core.builder import (  # noqa: F401
    ProcessBuilder, ProcessBuilderNamespace, UnknownPortError,
)
from repro.core.datatypes import (  # noqa: F401
    ArrayData, Bool, DataValue, Dict, Float, FolderData, Int, List, Str,
    to_data_value,
)
from repro.core.exit_code import ExitCode  # noqa: F401
from repro.core.ports import (  # noqa: F401
    UNSPECIFIED, InputPort, OutputPort, Port, PortNamespace,
    PortSerializationError, PortValidationError,
)
from repro.core.process import Process, ProcessKilled  # noqa: F401
from repro.core.process_functions import calcfunction, workfunction  # noqa: F401
from repro.core.process_spec import ProcessSpec  # noqa: F401
from repro.core.statemachine import (  # noqa: F401
    InvalidTransitionError, ProcessState, StateMachine, TERMINAL_STATES,
    TRANSITIONS,
)
from repro.core.workchain import (  # noqa: F401
    ToContext, WorkChain, append_, if_, return_, while_,
)
