"""ProcessSpec (paper §II.A.2–3): declarative input/output ports, nested
namespaces, exit codes, the WorkChain outline, and port exposing.

Ports declared here are the launch surface: ``Process.get_builder()``
mirrors ``spec.inputs`` as a :class:`~repro.core.builder.ProcessBuilder`,
and a port's ``serializer=`` (e.g. ``spec.input("n", valid_type=Int,
serializer=Int)``) wraps raw python values both at builder assignment and
at process construction. ``expose_inputs`` deep-copies the source ports
(via ``PortNamespace.absorb``), so re-declaring an exposed port afterwards
— the standard way to specialize an exposed namespace — never mutates the
source class's spec."""

from __future__ import annotations

from typing import Any, Callable

from repro.core.exit_code import ExitCode, ExitCodesNamespace
from repro.core.ports import InputPort, OutputPort, PortNamespace


class ProcessSpec:
    def __init__(self) -> None:
        self.inputs = PortNamespace("inputs")
        self.outputs = PortNamespace("outputs")
        self.exit_codes = ExitCodesNamespace()
        self._outline = None
        self._exposed_inputs: dict[tuple[type, str | None], list[str]] = {}
        self._sealed = False
        # default metadata ports (non_db attributes of the process node)
        self.input_namespace("metadata", dynamic=True, non_db=True)
        self.input("metadata.label", valid_type=str, required=False,
                   non_db=True)
        self.input("metadata.description", valid_type=str, required=False,
                   non_db=True)

    # -- declarative methods (later declarations override earlier ones) ------
    def input(self, name: str, **kwargs) -> None:
        non_db = kwargs.pop("non_db", False)
        self.inputs[name] = InputPort(name.rsplit(".", 1)[-1], non_db=non_db,
                                      **kwargs)

    def output(self, name: str, **kwargs) -> None:
        kwargs.setdefault("required", True)
        self.outputs[name] = OutputPort(name.rsplit(".", 1)[-1], **kwargs)

    def input_namespace(self, name: str, *, dynamic: bool = False,
                        non_db: bool = False) -> None:
        ns = self.inputs.create_namespace(name)
        ns.dynamic = dynamic
        ns.non_db = non_db

    def output_namespace(self, name: str, *, dynamic: bool = False) -> None:
        ns = self.outputs.create_namespace(name)
        ns.dynamic = dynamic

    def exit_code(self, status: int, label: str, message: str) -> None:
        if status < 0:
            raise ValueError("exit status must be a non-negative integer")
        self.exit_codes[label] = ExitCode(status, message, label)

    # -- outline (workchains, §II.B.3.a) --------------------------------------
    def outline(self, *instructions) -> None:
        from repro.core.workchain import _build_outline
        self._outline = _build_outline(instructions)

    def get_outline(self):
        return self._outline

    # -- exposing (§II.B.3.g) ---------------------------------------------------
    def expose_inputs(self, process_class, namespace: str | None = None,
                      exclude: tuple[str, ...] = (),
                      include: tuple[str, ...] | None = None) -> None:
        source = process_class.spec().inputs
        if namespace:
            target = self.inputs.create_namespace(namespace)
        else:
            target = self.inputs
        exclude = tuple(exclude) + ("metadata",) if not namespace else tuple(exclude)
        target.absorb(source, exclude=exclude, include=include)
        self._exposed_inputs[(process_class, namespace)] = [
            name for name in source
            if name not in exclude and (include is None or name in include)
        ]

    def expose_outputs(self, process_class, namespace: str | None = None,
                       exclude: tuple[str, ...] = (),
                       include: tuple[str, ...] | None = None) -> None:
        source = process_class.spec().outputs
        target = (self.outputs.create_namespace(namespace) if namespace
                  else self.outputs)
        target.absorb(source, exclude=tuple(exclude), include=include)

    def exposed_input_names(self, process_class,
                            namespace: str | None = None) -> list[str]:
        return self._exposed_inputs.get((process_class, namespace), [])

    # -- validation helpers -------------------------------------------------------
    def validate_inputs(self, values: dict[str, Any]) -> str | None:
        return self.inputs.validate(values)

    def validate_outputs(self, values: dict[str, Any]) -> str | None:
        return self.outputs.validate(values)
