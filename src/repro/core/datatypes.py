"""Database-storable value types (the paper's ``Int(3)``, ``Float`` etc.).

Every input/output that should enter the provenance graph must be a
``DataValue`` — the analogue of AiiDA's Data nodes. Values serialize to
JSON (+ raw array bytes for tensors) so the sqlite provenance store can
persist and rehydrate them. ``non_db`` ports bypass this requirement
(paper §II.A.1)."""

from __future__ import annotations

import base64
import io
from typing import Any

import numpy as _np


class DataValue:
    """Base class for storable values. Subclasses wrap a python payload."""

    _TYPE = "data"

    def __init__(self, value: Any = None):
        self._value = value
        self.uuid: str | None = None      # set once stored
        self.pk: int | None = None

    @property
    def value(self):
        return self._value

    @property
    def is_stored(self) -> bool:
        return self.pk is not None

    # -- serialization ---------------------------------------------------------
    def to_payload(self) -> dict:
        return {"type": self._TYPE, "value": self._value}

    @classmethod
    def from_payload(cls, payload: dict) -> "DataValue":
        t = payload.get("type", "data")
        klass = _TYPE_MAP.get(t, DataValue)
        return klass._from_payload(payload)

    @classmethod
    def _from_payload(cls, payload: dict) -> "DataValue":
        return cls(payload.get("value"))

    # -- conveniences -----------------------------------------------------------
    def __repr__(self) -> str:
        return f"{type(self).__name__}({self._value!r})"

    def __eq__(self, other) -> bool:
        if isinstance(other, DataValue):
            return self._value == other._value
        return self._value == other

    def __hash__(self):
        try:
            return hash((type(self).__name__, self._value))
        except TypeError:
            return id(self)


class Int(DataValue):
    _TYPE = "int"

    def __init__(self, value: int = 0):
        super().__init__(int(value))

    def __int__(self):
        return self._value

    def __add__(self, other):
        return Int(self._value + int(other))

    def __mul__(self, other):
        return Int(self._value * int(other))


class Float(DataValue):
    _TYPE = "float"

    def __init__(self, value: float = 0.0):
        super().__init__(float(value))

    def __float__(self):
        return self._value

    def __add__(self, other):
        return Float(self._value + float(other))

    def __mul__(self, other):
        return Float(self._value * float(other))


class Bool(DataValue):
    _TYPE = "bool"

    def __init__(self, value: bool = False):
        super().__init__(bool(value))

    def __bool__(self):
        return self._value


class Str(DataValue):
    _TYPE = "str"

    def __init__(self, value: str = ""):
        super().__init__(str(value))

    def __str__(self):
        return self._value


class Dict(DataValue):
    _TYPE = "dict"

    def __init__(self, value: dict | None = None):
        super().__init__(dict(value or {}))

    def __getitem__(self, k):
        return self._value[k]

    def get(self, k, default=None):
        return self._value.get(k, default)

    def keys(self):
        return self._value.keys()

    def items(self):
        return self._value.items()


class List(DataValue):
    _TYPE = "list"

    def __init__(self, value: list | None = None):
        super().__init__(list(value or []))

    def __getitem__(self, i):
        return self._value[i]

    def __len__(self):
        return len(self._value)

    def __iter__(self):
        return iter(self._value)


class ArrayData(DataValue):
    """Numpy/JAX array payload, persisted as base64 .npy bytes."""

    _TYPE = "array"

    def __init__(self, value):
        arr = _np.asarray(value)
        super().__init__(arr)

    def to_payload(self) -> dict:
        buf = io.BytesIO()
        _np.save(buf, self._value, allow_pickle=False)
        return {"type": self._TYPE,
                "npy_b64": base64.b64encode(buf.getvalue()).decode()}

    @classmethod
    def _from_payload(cls, payload: dict) -> "ArrayData":
        raw = base64.b64decode(payload["npy_b64"])
        return cls(_np.load(io.BytesIO(raw), allow_pickle=False))

    def __eq__(self, other):
        o = other._value if isinstance(other, DataValue) else other
        try:
            return bool(_np.array_equal(self._value, o))
        except Exception:  # noqa: BLE001
            return False

    def __hash__(self):
        return id(self)


class FolderData(DataValue):
    """A named set of file payloads (the CalcJob retrieve target)."""

    _TYPE = "folder"

    def __init__(self, files: dict[str, bytes] | None = None):
        super().__init__({k: bytes(v) for k, v in (files or {}).items()})

    def to_payload(self) -> dict:
        return {"type": self._TYPE,
                "files": {k: base64.b64encode(v).decode()
                          for k, v in self._value.items()}}

    @classmethod
    def _from_payload(cls, payload: dict) -> "FolderData":
        return cls({k: base64.b64decode(v)
                    for k, v in payload.get("files", {}).items()})

    def get_bytes(self, name: str) -> bytes:
        return self._value[name]

    def names(self) -> list[str]:
        return sorted(self._value)

    def __hash__(self):
        return id(self)


_TYPE_MAP = {c._TYPE: c for c in
             (DataValue, Int, Float, Bool, Str, Dict, List, ArrayData,
              FolderData)}


def to_data_value(obj: Any) -> DataValue:
    """Coerce a raw python object into a storable DataValue."""
    if isinstance(obj, DataValue):
        return obj
    if isinstance(obj, bool):
        return Bool(obj)
    if isinstance(obj, int):
        return Int(obj)
    if isinstance(obj, float):
        return Float(obj)
    if isinstance(obj, str):
        return Str(obj)
    if isinstance(obj, dict):
        return Dict(obj)
    if isinstance(obj, (list, tuple)):
        return List(list(obj))
    if isinstance(obj, _np.ndarray):
        return ArrayData(obj)
    try:  # jax arrays quack like numpy
        import jax
        if isinstance(obj, jax.Array):
            return ArrayData(_np.asarray(obj))
    except Exception:  # noqa: BLE001
        pass
    raise TypeError(f"cannot convert {type(obj).__name__} to a storable "
                    "DataValue; wrap it or mark the port non_db")
