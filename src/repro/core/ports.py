"""Ports and port namespaces (paper §II.A.1).

``Port`` carries valid_type / validator / default / required / non_db /
serializer; ``PortNamespace`` is a Mapping subclass of Port, so namespaces
nest. A namespace validates iff all nested ports and itself validate.
``dynamic`` namespaces accept undeclared keys (used by exposed/dynamic
workchain inputs, §II.B.3).

Two sentinels matter here: ``_NO_DEFAULT`` (the port declares no default)
and ``UNSPECIFIED`` (the caller did not provide a value). The latter keeps
an *explicitly passed* ``None`` distinguishable from an absent key — a
required port reports "was not provided" only when the key is truly
missing, and optional typed ports reject an explicit ``None`` instead of
silently accepting it.

A port declared with ``serializer=`` (e.g. ``valid_type=Int,
serializer=Int``) transparently wraps raw Python values that are not
already of the valid type, so ``builder.n = 3`` and ``run(P, n=3)`` store
a provenance-complete ``Int(3)`` without caller boilerplate (the AiiDA 1.0
port-serializer contract).
"""

from __future__ import annotations

import copy
from collections.abc import Mapping, MutableMapping
from typing import Any, Callable

class _Sentinel:
    """A singleton marker that survives copy/deepcopy with identity
    intact — ports are deep-copied on ``absorb`` and an ``is``-compared
    sentinel must not be duplicated in the copy."""

    _instances: dict[str, "_Sentinel"] = {}

    def __new__(cls, tag: str):
        if tag not in cls._instances:
            self = super().__new__(cls)
            self._tag = tag
            cls._instances[tag] = self
        return cls._instances[tag]

    def __repr__(self) -> str:
        return self._tag

    def __bool__(self) -> bool:
        return False

    def __copy__(self) -> "_Sentinel":
        return self

    def __deepcopy__(self, memo) -> "_Sentinel":
        return self

    def __reduce__(self):
        return (_Sentinel, (self._tag,))


_NO_DEFAULT = _Sentinel("NO_DEFAULT")

#: the caller did not provide a value for this port (≠ an explicit None)
UNSPECIFIED = _Sentinel("UNSPECIFIED")

SEPARATOR = "."


class PortValidationError(ValueError):
    """Raised when a value fails port validation."""


class PortSerializationError(PortValidationError):
    """Raised when a port serializer cannot wrap a raw value."""


class Port:
    def __init__(self, name: str, *, valid_type: type | tuple[type, ...] | None = None,
                 validator: Callable[[Any], str | None] | None = None,
                 default: Any = _NO_DEFAULT, required: bool = True,
                 non_db: bool = False, exclude_from_hash: bool = False,
                 serializer: Callable[[Any], Any] | None = None,
                 help: str = ""):
        self.name = name
        if valid_type is not None and not isinstance(valid_type, tuple):
            valid_type = (valid_type,)
        self.valid_type = valid_type
        self.validator = validator
        self._default = default
        self.required = required and default is _NO_DEFAULT
        self.non_db = non_db
        # excluded from the caching input fingerprint (tolerances,
        # thresholds, … — inputs that do not change what is computed);
        # unlike non_db the value IS still stored and linked in provenance
        self.exclude_from_hash = exclude_from_hash
        self.serializer = serializer
        self.help = help

    # ------------------------------------------------------------------
    @property
    def has_default(self) -> bool:
        return self._default is not _NO_DEFAULT

    @property
    def default(self) -> Any:
        if not self.has_default:
            raise AttributeError(f"port {self.name!r} has no default")
        return self._default() if callable(self._default) else self._default

    def serialize(self, value: Any, breadcrumbs: str = "") -> Any:
        """Wrap a raw value through the port's serializer. Values already
        of the valid type (or with no serializer declared) pass through
        untouched; a serializer failure raises with the port path."""
        if (self.serializer is None or value is UNSPECIFIED
                or value is None):
            return value
        if self.valid_type is not None and isinstance(value, self.valid_type):
            return value
        path = (f"{breadcrumbs}{SEPARATOR}{self.name}"
                if breadcrumbs else self.name)
        try:
            return self.serializer(value)
        except Exception as exc:  # noqa: BLE001 — reported with the path
            raise PortSerializationError(
                f"port '{path}': could not serialize "
                f"{type(value).__name__} value {value!r}: {exc}") from exc

    def validate(self, value: Any, breadcrumbs: str = "") -> str | None:
        """Return an error string, or None when valid. ``UNSPECIFIED``
        means the key was absent; ``None`` means the caller explicitly
        passed None — the two produce different diagnostics."""
        path = f"{breadcrumbs}{SEPARATOR}{self.name}" if breadcrumbs else self.name
        if value is UNSPECIFIED:
            if self.required:
                return f"required port '{path}' was not provided"
            return None
        if value is None:
            if self.valid_type is not None and \
                    not any(t is type(None) for t in self.valid_type):
                types = tuple(t.__name__ for t in self.valid_type)
                prefix = "required " if self.required else ""
                return (f"{prefix}port '{path}' was explicitly passed None, "
                        f"which is not one of {types}")
            return None
        if self.valid_type is not None and not isinstance(value, self.valid_type):
            types = tuple(t.__name__ for t in self.valid_type)
            return (f"port '{path}': value of type "
                    f"{type(value).__name__} is not one of {types}")
        if self.validator is not None:
            err = self.validator(value)
            if err is not None:
                return f"port '{path}': {err}"
        return None

    def __repr__(self) -> str:
        extra = f", help={self.help!r}" if self.help else ""
        return (f"{type(self).__name__}({self.name!r}, "
                f"required={self.required}, non_db={self.non_db}{extra})")


class InputPort(Port):
    pass


class OutputPort(Port):
    pass


class PortNamespace(Port, MutableMapping):
    """A Port that is also a mapping of named sub-ports (nests freely)."""

    def __init__(self, name: str = "", *, dynamic: bool = False,
                 required: bool = False, non_db: bool = False,
                 exclude_from_hash: bool = False,
                 valid_type: Any = None, validator: Any = None,
                 default: Any = _NO_DEFAULT, help: str = ""):
        super().__init__(name, valid_type=valid_type, validator=validator,
                         default=default, required=required, non_db=non_db,
                         exclude_from_hash=exclude_from_hash, help=help)
        self.dynamic = dynamic
        self._ports: dict[str, Port] = {}

    # -- mapping protocol ----------------------------------------------------
    def __getitem__(self, key: str) -> Port:
        head, _, tail = key.partition(SEPARATOR)
        port = self._ports[head]
        if tail:
            if not isinstance(port, PortNamespace):
                raise KeyError(key)
            return port[tail]
        return port

    def __setitem__(self, key: str, port: Port) -> None:
        head, _, tail = key.partition(SEPARATOR)
        if tail:
            ns = self._ports.setdefault(head, PortNamespace(head))
            if not isinstance(ns, PortNamespace):
                raise KeyError(f"{head!r} exists and is not a namespace")
            ns[tail] = port
        else:
            self._ports[head] = port

    def __delitem__(self, key: str) -> None:
        del self._ports[key]

    def __iter__(self):
        return iter(self._ports)

    def __len__(self) -> int:
        return len(self._ports)

    # -- declaration helpers ---------------------------------------------------
    def create_namespace(self, key: str, **kwargs) -> "PortNamespace":
        """Recursively create nested namespaces along a dotted path."""
        head, _, tail = key.partition(SEPARATOR)
        if head not in self._ports:
            self._ports[head] = PortNamespace(head, **(kwargs if not tail else {}))
        ns = self._ports[head]
        if not isinstance(ns, PortNamespace):
            raise ValueError(f"{head!r} is already a leaf port")
        if tail:
            return ns.create_namespace(tail, **kwargs)
        return ns

    def absorb(self, other: "PortNamespace", exclude: tuple[str, ...] = (),
               include: tuple[str, ...] | None = None) -> None:
        """Copy ports from another namespace (expose_inputs machinery).

        Ports (and nested namespaces) are *deep-copied*: the exposing spec
        must never share mutable Port objects with the source class, or
        mutating one spec (e.g. re-declaring a port after exposing) would
        silently rewrite the other."""
        for name, port in other.items():
            if include is not None and name not in include:
                continue
            if name in exclude:
                continue
            self._ports[name] = copy.deepcopy(port)
        if other.dynamic:
            self.dynamic = True

    # -- serialization (port serializer= contract) ------------------------------
    def serialize(self, values: Any, breadcrumbs: str = "") -> dict[str, Any]:
        """Walk the namespace tree applying leaf-port serializers to the
        given values; undeclared keys (dynamic namespaces) pass through."""
        path = (f"{breadcrumbs}{SEPARATOR}{self.name}"
                if breadcrumbs and self.name else (self.name or breadcrumbs))
        if values is None or values is UNSPECIFIED:
            return {}
        out: dict[str, Any] = {}
        for key, value in dict(values).items():
            port = self._ports.get(key)
            if isinstance(port, PortNamespace) and isinstance(value, Mapping):
                out[key] = port.serialize(value, path)
            elif port is not None:
                out[key] = port.serialize(value, path)
            else:
                out[key] = value
        return out

    # -- validation -------------------------------------------------------------
    def validate(self, values: Any, breadcrumbs: str = "") -> str | None:
        path = (f"{breadcrumbs}{SEPARATOR}{self.name}"
                if breadcrumbs and self.name else (self.name or breadcrumbs))
        if values is UNSPECIFIED:
            values = {}
        values = dict(values or {})
        # declared ports
        for name, port in self._ports.items():
            value = values.pop(name, UNSPECIFIED)
            if value is UNSPECIFIED and port.has_default:
                value = port.default
            err = port.validate(value, path)
            if err is not None:
                return err
        # leftovers
        if values and not self.dynamic:
            return (f"namespace '{path or '<root>'}' does not accept "
                    f"undeclared ports: {sorted(values)}")
        if self.validator is not None:
            err = self.validator(values)
            if err is not None:
                return f"namespace '{path}': {err}"
        return None

    def defaults(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for name, port in self._ports.items():
            if isinstance(port, PortNamespace):
                sub = port.defaults()
                if sub:
                    out[name] = sub
            elif port.has_default:
                out[name] = port.default
        return out

    def non_db_keys(self) -> set[str]:
        return {name for name, port in self._ports.items() if port.non_db}

    def project(self, values: Mapping[str, Any]) -> dict[str, Any]:
        """Split values into (db-storable, non-db) according to port flags."""
        return {k: v for k, v in values.items() if k not in self.non_db_keys()}
