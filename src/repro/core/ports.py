"""Ports and port namespaces (paper §II.A.1).

``Port`` carries valid_type / validator / default / required / non_db;
``PortNamespace`` is a Mapping subclass of Port, so namespaces nest. A
namespace validates iff all nested ports and itself validate. ``dynamic``
namespaces accept undeclared keys (used by exposed/dynamic workchain
inputs, §II.B.3).
"""

from __future__ import annotations

from collections.abc import Mapping, MutableMapping
from typing import Any, Callable

_NO_DEFAULT = object()

SEPARATOR = "."


class PortValidationError(ValueError):
    """Raised when a value fails port validation."""


class Port:
    def __init__(self, name: str, *, valid_type: type | tuple[type, ...] | None = None,
                 validator: Callable[[Any], str | None] | None = None,
                 default: Any = _NO_DEFAULT, required: bool = True,
                 non_db: bool = False, exclude_from_hash: bool = False,
                 help: str = ""):
        self.name = name
        if valid_type is not None and not isinstance(valid_type, tuple):
            valid_type = (valid_type,)
        self.valid_type = valid_type
        self.validator = validator
        self._default = default
        self.required = required and default is _NO_DEFAULT
        self.non_db = non_db
        # excluded from the caching input fingerprint (tolerances,
        # thresholds, … — inputs that do not change what is computed);
        # unlike non_db the value IS still stored and linked in provenance
        self.exclude_from_hash = exclude_from_hash
        self.help = help

    # ------------------------------------------------------------------
    @property
    def has_default(self) -> bool:
        return self._default is not _NO_DEFAULT

    @property
    def default(self) -> Any:
        if not self.has_default:
            raise AttributeError(f"port {self.name!r} has no default")
        return self._default() if callable(self._default) else self._default

    def validate(self, value: Any, breadcrumbs: str = "") -> str | None:
        """Return an error string, or None when valid."""
        path = f"{breadcrumbs}{SEPARATOR}{self.name}" if breadcrumbs else self.name
        if value is None:
            if self.required:
                return f"required port '{path}' was not provided"
            return None
        if self.valid_type is not None and not isinstance(value, self.valid_type):
            types = tuple(t.__name__ for t in self.valid_type)
            return (f"port '{path}': value of type "
                    f"{type(value).__name__} is not one of {types}")
        if self.validator is not None:
            err = self.validator(value)
            if err is not None:
                return f"port '{path}': {err}"
        return None

    def __repr__(self) -> str:
        return (f"{type(self).__name__}({self.name!r}, "
                f"required={self.required}, non_db={self.non_db})")


class InputPort(Port):
    pass


class OutputPort(Port):
    pass


class PortNamespace(Port, MutableMapping):
    """A Port that is also a mapping of named sub-ports (nests freely)."""

    def __init__(self, name: str = "", *, dynamic: bool = False,
                 required: bool = False, non_db: bool = False,
                 exclude_from_hash: bool = False,
                 valid_type: Any = None, validator: Any = None,
                 default: Any = _NO_DEFAULT, help: str = ""):
        super().__init__(name, valid_type=valid_type, validator=validator,
                         default=default, required=required, non_db=non_db,
                         exclude_from_hash=exclude_from_hash, help=help)
        self.dynamic = dynamic
        self._ports: dict[str, Port] = {}

    # -- mapping protocol ----------------------------------------------------
    def __getitem__(self, key: str) -> Port:
        head, _, tail = key.partition(SEPARATOR)
        port = self._ports[head]
        if tail:
            if not isinstance(port, PortNamespace):
                raise KeyError(key)
            return port[tail]
        return port

    def __setitem__(self, key: str, port: Port) -> None:
        head, _, tail = key.partition(SEPARATOR)
        if tail:
            ns = self._ports.setdefault(head, PortNamespace(head))
            if not isinstance(ns, PortNamespace):
                raise KeyError(f"{head!r} exists and is not a namespace")
            ns[tail] = port
        else:
            self._ports[head] = port

    def __delitem__(self, key: str) -> None:
        del self._ports[key]

    def __iter__(self):
        return iter(self._ports)

    def __len__(self) -> int:
        return len(self._ports)

    # -- declaration helpers ---------------------------------------------------
    def create_namespace(self, key: str, **kwargs) -> "PortNamespace":
        """Recursively create nested namespaces along a dotted path."""
        head, _, tail = key.partition(SEPARATOR)
        if head not in self._ports:
            self._ports[head] = PortNamespace(head, **(kwargs if not tail else {}))
        ns = self._ports[head]
        if not isinstance(ns, PortNamespace):
            raise ValueError(f"{head!r} is already a leaf port")
        if tail:
            return ns.create_namespace(tail, **kwargs)
        return ns

    def absorb(self, other: "PortNamespace", exclude: tuple[str, ...] = (),
               include: tuple[str, ...] | None = None) -> None:
        """Copy ports from another namespace (expose_inputs machinery)."""
        for name, port in other.items():
            if include is not None and name not in include:
                continue
            if name in exclude:
                continue
            self._ports[name] = port
        if other.dynamic:
            self.dynamic = True

    # -- validation -------------------------------------------------------------
    def validate(self, values: Any, breadcrumbs: str = "") -> str | None:
        path = (f"{breadcrumbs}{SEPARATOR}{self.name}"
                if breadcrumbs and self.name else (self.name or breadcrumbs))
        values = dict(values or {})
        # declared ports
        for name, port in self._ports.items():
            value = values.pop(name, None)
            if value is None and port.has_default:
                value = port.default
            err = port.validate(value, path)
            if err is not None:
                return err
        # leftovers
        if values and not self.dynamic:
            return (f"namespace '{path or '<root>'}' does not accept "
                    f"undeclared ports: {sorted(values)}")
        if self.validator is not None:
            err = self.validator(values)
            if err is not None:
                return f"namespace '{path}': {err}"
        return None

    def defaults(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for name, port in self._ports.items():
            if isinstance(port, PortNamespace):
                sub = port.defaults()
                if sub:
                    out[name] = sub
            elif port.has_default:
                out[name] = port.default
        return out

    def non_db_keys(self) -> set[str]:
        return {name for name, port in self._ports.items() if port.non_db}

    def project(self, values: Mapping[str, Any]) -> dict[str, Any]:
        """Split values into (db-storable, non-db) according to port flags."""
        return {k: v for k, v in values.items() if k not in self.non_db_keys()}
