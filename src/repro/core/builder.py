"""ProcessBuilder (paper §II.A; AiiDA 1.0 launch API).

``MyProcess.get_builder()`` returns a :class:`ProcessBuilder` that mirrors
the class's ``PortNamespace`` tree with attribute access::

    b = MyWorkChain.get_builder()
    b.sub.n = 3              # nested namespace, validated on assignment
    b.metadata.label = "run" # metadata ports work the same way
    run_get_node(b)          # engine/launch.py accepts builders directly

Every assignment is validated against the target port immediately — a bad
type raises :class:`PortValidationError` *at assignment time* with the full
dotted port path, instead of a dict typo surfacing at runtime. Ports with a
``serializer=`` wrap raw Python values on assignment (``b.sub.n = 3``
stores ``Int(3)``), keeping provenance complete without boilerplate.

Builders also support dotted-path get/set (``b["sub.n"]``), recursive
``_merge()`` of plain dicts, and ``_inputs(prune=True)`` which drops unset
optionals and empty namespaces — exactly what the launchers hand to the
process constructor.
"""

from __future__ import annotations

from collections.abc import Mapping, MutableMapping
from typing import Any

from repro.core.ports import (
    SEPARATOR, Port, PortNamespace, PortValidationError,
)


class UnknownPortError(PortValidationError, AttributeError):
    """Assignment to a port that does not exist in a non-dynamic
    namespace. Subclasses both PortValidationError (the documented
    assignment-failure contract) and AttributeError (the natural
    exception for ``builder.typo = ...``), so either handler catches it."""


class ProcessBuilderNamespace(MutableMapping):
    """One level of a builder, mirroring one ``PortNamespace``."""

    def __init__(self, port_namespace: PortNamespace, breadcrumbs: str = ""):
        # bypass __setattr__ (which routes to ports) for internals
        object.__setattr__(self, "_port_namespace", port_namespace)
        object.__setattr__(self, "_breadcrumbs", breadcrumbs)
        object.__setattr__(self, "_data", {})
        for name, port in port_namespace.items():
            if isinstance(port, PortNamespace):
                self._data[name] = ProcessBuilderNamespace(
                    port, self._path(name))
        object.__setattr__(self, "__doc__", self._build_doc())

    # -- helpers -----------------------------------------------------------
    def _path(self, name: str) -> str:
        return (f"{self._breadcrumbs}{SEPARATOR}{name}"
                if self._breadcrumbs else name)

    def _build_doc(self) -> str:
        ns = self._port_namespace
        lines = [f"Inputs for namespace '{self._breadcrumbs or '<root>'}'"
                 + (" (dynamic)" if ns.dynamic else "") + ":"]
        if ns.help:
            lines.append(f"  {ns.help}")
        for name, port in ns.items():
            if isinstance(port, PortNamespace):
                lines.append(f"  {name}: namespace"
                             + (" (dynamic)" if port.dynamic else ""))
                continue
            types = ("|".join(t.__name__ for t in port.valid_type)
                     if port.valid_type else "any")
            req = "required" if port.required else "optional"
            tail = f" — {port.help}" if port.help else ""
            lines.append(f"  {name}: {types}, {req}{tail}")
        return "\n".join(lines)

    # -- attribute protocol ------------------------------------------------
    def __setattr__(self, name: str, value: Any) -> None:
        if name.startswith("_"):
            object.__setattr__(self, name, value)
        else:
            self[name] = value

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        try:
            return self[name]
        except KeyError:
            raise AttributeError(
                f"no input '{self._path(name)}' set; declared ports: "
                f"{sorted(self._port_namespace)}") from None

    def __dir__(self):
        return sorted(set(list(super().__dir__())
                          + list(self._port_namespace)
                          + list(self._data)))

    # -- mapping protocol --------------------------------------------------
    def __setitem__(self, key: str, value: Any) -> None:
        head, _, tail = key.partition(SEPARATOR)
        if tail:
            target = self._data.get(head)
            if not isinstance(target, ProcessBuilderNamespace):
                raise KeyError(f"'{self._path(head)}' is not a namespace")
            target[tail] = value
            return
        port = self._port_namespace.get(head)
        if isinstance(port, PortNamespace):
            if not isinstance(value, Mapping):
                raise PortValidationError(
                    f"port '{self._path(head)}' is a namespace; assign a "
                    f"mapping, not {type(value).__name__}")
            # replace atomically: validate into a fresh namespace and swap
            # only on success, so a failed assignment leaves the previous
            # contents intact (no partial write)
            fresh = ProcessBuilderNamespace(port, self._path(head))
            fresh._merge(value)
            self._data[head] = fresh
            return
        if port is None:
            if not self._port_namespace.dynamic:
                raise UnknownPortError(
                    f"'{self._path(head)}' is not a declared input port; "
                    f"declared ports: {sorted(self._port_namespace)}")
            self._data[head] = value
            return
        value = port.serialize(value, self._breadcrumbs)
        err = port.validate(value, self._breadcrumbs)
        if err is not None:
            raise PortValidationError(err)
        self._data[head] = value

    def __getitem__(self, key: str):
        head, _, tail = key.partition(SEPARATOR)
        value = self._data[head]
        if tail:
            if not isinstance(value, ProcessBuilderNamespace):
                raise KeyError(key)
            return value[tail]
        return value

    def __delitem__(self, key: str) -> None:
        head, _, tail = key.partition(SEPARATOR)
        if tail:
            del self._data[head][tail]
            return
        value = self._data.get(head)
        if isinstance(value, ProcessBuilderNamespace):
            value.clear()
        else:
            del self._data[head]

    def __iter__(self):
        for key, value in self._data.items():
            if isinstance(value, ProcessBuilderNamespace) and not len(value):
                continue
            yield key

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def clear(self) -> None:
        for key in list(self._data):
            value = self._data[key]
            if isinstance(value, ProcessBuilderNamespace):
                value.clear()
            else:
                del self._data[key]

    # -- bulk updates ------------------------------------------------------
    def _merge(self, values: Mapping[str, Any] | None = None, **kwargs) -> None:
        """Recursively merge a nested dict into this namespace; every leaf
        goes through the normal per-assignment validation/serialization."""
        merged = dict(values or {})
        merged.update(kwargs)
        for key, value in merged.items():
            sub = self._data.get(key)
            if isinstance(sub, ProcessBuilderNamespace) and \
                    isinstance(value, Mapping):
                sub._merge(value)
            else:
                self[key] = value

    def _inputs(self, prune: bool = True) -> dict[str, Any]:
        """The accumulated inputs as a plain nested dict. With ``prune``
        (the launcher default), unset optionals and empty namespaces are
        simply absent — the process constructor applies port defaults."""
        out: dict[str, Any] = {}
        for key, value in self._data.items():
            if isinstance(value, ProcessBuilderNamespace):
                sub = value._inputs(prune=prune)
                if sub or not prune:
                    out[key] = sub
            else:
                out[key] = value
        return out

    def __repr__(self) -> str:
        return (f"{type(self).__name__}"
                f"('{self._breadcrumbs or '<root>'}', "
                f"{self._inputs(prune=True)!r})")


class ProcessBuilder(ProcessBuilderNamespace):
    """The root builder, bound to a process class (launchable as-is)."""

    def __init__(self, process_class: type):
        object.__setattr__(self, "_process_class", process_class)
        super().__init__(process_class.spec().inputs)

    @property
    def process_class(self) -> type:
        return self._process_class

    def __repr__(self) -> str:
        return (f"ProcessBuilder({self._process_class.__name__}, "
                f"{self._inputs(prune=True)!r})")


def expand_launch_target(process, inputs: Mapping[str, Any] | None = None
                         ) -> tuple[type, dict[str, Any]]:
    """Normalize the two launcher call shapes — ``(ProcessClass, **inputs)``
    or ``(builder, **overrides)`` — into ``(process_class, inputs)``."""
    if isinstance(process, ProcessBuilder):
        merged = process._inputs(prune=True)
        for key, value in dict(inputs or {}).items():
            if isinstance(merged.get(key), dict) and isinstance(value, Mapping):
                merged[key].update(value)
            else:
                merged[key] = value
        return process._process_class, merged
    if isinstance(process, type):
        return process, dict(inputs or {})
    raise TypeError(
        f"expected a Process class or a ProcessBuilder, got "
        f"{type(process).__name__}")
