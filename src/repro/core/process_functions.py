"""calcfunction / workfunction decorators (paper §II.B.1–2).

A decorated plain Python function becomes a full process when called: the
engine introspects the signature to build a ProcessSpec on the fly, creates
the provenance node, links inputs, runs the body synchronously (process
functions intentionally block — §II.B.2), and links outputs.

calcfunction — *creates* data (CREATE links);
workfunction — *orchestrates*: returns existing data (RETURN links) and the
processes it calls get CALL links (fig. 2).
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Callable

from repro.core.datatypes import DataValue, to_data_value
from repro.core.exit_code import ExitCode
from repro.core.process import Process
from repro.core.process_spec import ProcessSpec
from repro.provenance.store import NodeType


def _make_function_process(fn: Callable, node_type: NodeType) -> type:
    sig = inspect.signature(fn)
    pos_names = [p.name for p in sig.parameters.values()
                 if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)]
    has_var_kw = any(p.kind is p.VAR_KEYWORD for p in sig.parameters.values())

    from repro.caching.hashing import source_salt

    class FunctionProcess(Process):
        NODE_TYPE = node_type
        _func = staticmethod(fn)
        _pos_names = pos_names
        # editing the function body changes the fingerprint, so stale
        # cached results of the old implementation are never reused
        _cache_extra_salt = source_salt(fn)

        @classmethod
        def define(cls, spec: ProcessSpec) -> None:
            super().define(spec)
            for p in sig.parameters.values():
                if p.kind is p.VAR_KEYWORD:
                    continue
                kwargs: dict[str, Any] = {"valid_type": DataValue}
                ann = p.annotation
                if isinstance(ann, type) and issubclass(ann, DataValue):
                    kwargs["valid_type"] = ann   # type annotations augment
                if p.default is not inspect.Parameter.empty:
                    kwargs["default"] = p.default
                    kwargs["required"] = False
                spec.input(p.name, **kwargs)
            if has_var_kw:
                spec.inputs.dynamic = True
            spec.outputs.dynamic = True

        async def run(self):
            kwargs = {k: v for k, v in self.inputs.items()
                      if k != "metadata"}
            result = self._func(**kwargs)
            if isinstance(result, ExitCode):
                return result
            if result is not None:
                if isinstance(result, dict) and not isinstance(result, DataValue):
                    for k, v in result.items():
                        self.out(k, to_data_value(v))
                    # so a cache hit can reproduce the dict-shaped return
                    # even when the dict has a single 'result' key; stashed
                    # so it commits with the terminal transaction
                    self.stash_attributes({"returns_dict": True})
                else:
                    self.out("result", to_data_value(result))
            self._result_value = result
            return None

    FunctionProcess.__name__ = fn.__name__
    FunctionProcess.__qualname__ = fn.__name__
    FunctionProcess.__module__ = fn.__module__
    return FunctionProcess


def _process_function(fn: Callable, node_type: NodeType) -> Callable:
    process_class = _make_function_process(fn, node_type)
    sig = inspect.signature(fn)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        bound = sig.bind(*args, **kwargs)
        inputs: dict[str, Any] = {}
        for name, value in bound.arguments.items():
            param = sig.parameters[name]
            if param.kind is param.VAR_KEYWORD:
                for k2, v2 in value.items():
                    inputs[k2] = to_data_value(v2)
            else:
                inputs[name] = to_data_value(value)
        from repro.engine.runner import default_runner
        runner = default_runner()
        process = process_class(inputs=inputs, runner=runner)
        exit_code = runner.run_sync(process)
        if exit_code.status == 999:
            logs = runner.store.get_logs(process.pk)
            err = logs[-1]["message"] if logs else "unknown error"
            raise RuntimeError(
                f"{fn.__name__} (pk={process.pk}) excepted:\n{err}")
        result = getattr(process, "_result_value", None)
        if result is None and process.outputs:
            return _outputs_as_result(process)  # cache hit: run() never
            # executed, the cloned outputs carry the return value
        if result is None and isinstance(exit_code, ExitCode) and \
                not exit_code.is_finished_ok:
            return exit_code
        if isinstance(result, dict) and not isinstance(result, DataValue):
            return {k: to_data_value(v) for k, v in result.items()}
        return to_data_value(result) if result is not None else None

    wrapper.process_class = process_class
    wrapper.run_get_node = lambda *a, **kw: _run_get_node(wrapper, process_class,
                                                          sig, *a, **kw)
    return wrapper


def _outputs_as_result(process: Process) -> Any:
    """Rebuild a cache-hit process's return value from its cloned outputs,
    with the same shape the original call produced (the `returns_dict`
    attribute is carried over from the cache source)."""
    import json

    outputs = dict(process.outputs)
    node = process.store.get_node(process.pk) or {}
    attrs = json.loads(node.get("attributes") or "{}")
    if not attrs.get("returns_dict") and set(outputs) == {"result"}:
        return outputs["result"]
    return outputs


def _run_get_node(wrapper, process_class, sig, *args, **kwargs):
    from repro.engine.runner import default_runner
    bound = sig.bind(*args, **kwargs)
    inputs = {}
    for name, value in bound.arguments.items():
        param = sig.parameters[name]
        if param.kind is param.VAR_KEYWORD:
            for k2, v2 in value.items():
                inputs[k2] = to_data_value(v2)
        else:
            inputs[name] = to_data_value(value)
    runner = default_runner()
    process = process_class(inputs=inputs, runner=runner)
    exit_code = runner.run_sync(process)
    result = getattr(process, "_result_value", None)
    if result is None and process.outputs:
        out = _outputs_as_result(process)
        if isinstance(out, dict):
            # cold dict-returns come back as one Dict DataValue here;
            # rebuild that shape from the cloned outputs
            out = to_data_value({k: v.value if isinstance(v, DataValue)
                                 else v for k, v in out.items()})
        return out, process, exit_code
    return (to_data_value(result) if result is not None else None,
            process, exit_code)


def calcfunction(fn: Callable) -> Callable:
    """Lift a plain function into a provenance-tracked calculation."""
    return _process_function(fn, NodeType.CALC_FUNCTION)


def workfunction(fn: Callable) -> Callable:
    """Lift a plain function into a provenance-tracked workflow."""
    return _process_function(fn, NodeType.WORK_FUNCTION)
