"""Extended finite-state machine (paper §III.B, fig. 6).

States carry extended data (the process instance itself holds it); the
machine enforces the transition table and fires the three hooks around every
transition::

    on_exiting()            # about to leave the current state
    on_entering(new_state)  # about to enter new_state
    <state assigned>
    on_entered(from_state)  # transition finished — persistence + broadcast

This hook discipline is what lets the engine guarantee a checkpoint exists
for every state the outside world can observe.
"""

from __future__ import annotations

import enum
from typing import Callable


class ProcessState(str, enum.Enum):
    CREATED = "created"
    RUNNING = "running"
    WAITING = "waiting"
    PAUSED = "paused"
    FINISHED = "finished"
    EXCEPTED = "excepted"
    KILLED = "killed"

    @property
    def is_terminal(self) -> bool:
        return self in TERMINAL_STATES


TERMINAL_STATES = frozenset(
    {ProcessState.FINISHED, ProcessState.EXCEPTED, ProcessState.KILLED})

TRANSITIONS: dict[ProcessState, frozenset[ProcessState]] = {
    ProcessState.CREATED: frozenset({
        ProcessState.RUNNING, ProcessState.PAUSED, ProcessState.EXCEPTED,
        ProcessState.KILLED}),
    ProcessState.RUNNING: frozenset({
        ProcessState.RUNNING, ProcessState.WAITING, ProcessState.PAUSED,
        ProcessState.FINISHED, ProcessState.EXCEPTED, ProcessState.KILLED}),
    ProcessState.WAITING: frozenset({
        ProcessState.RUNNING, ProcessState.WAITING, ProcessState.PAUSED,
        ProcessState.FINISHED, ProcessState.EXCEPTED, ProcessState.KILLED}),
    ProcessState.PAUSED: frozenset({
        ProcessState.RUNNING, ProcessState.WAITING, ProcessState.EXCEPTED,
        ProcessState.KILLED}),
    ProcessState.FINISHED: frozenset(),
    ProcessState.EXCEPTED: frozenset(),
    ProcessState.KILLED: frozenset(),
}


class InvalidTransitionError(RuntimeError):
    pass


class StateMachine:
    """Mixin driving the state field with hook discipline."""

    def __init__(self) -> None:
        self._sm_state: ProcessState = ProcessState.CREATED
        self._paused_from: ProcessState | None = None

    @property
    def state(self) -> ProcessState:
        return self._sm_state

    @property
    def is_terminated(self) -> bool:
        return self._sm_state.is_terminal

    # hooks — subclasses override
    def on_exiting(self) -> None:  # noqa: B027
        pass

    def on_entering(self, state: ProcessState) -> None:  # noqa: B027
        pass

    def on_entered(self, from_state: ProcessState) -> None:  # noqa: B027
        pass

    def transition_to(self, new_state: ProcessState) -> None:
        current = self._sm_state
        if new_state not in TRANSITIONS[current]:
            raise InvalidTransitionError(
                f"invalid transition {current.value} -> {new_state.value}")
        if new_state is ProcessState.PAUSED:
            self._paused_from = current
        self.on_exiting()
        self.on_entering(new_state)
        self._sm_state = new_state
        self.on_entered(current)

    def resume_from_pause(self) -> ProcessState:
        """PAUSED -> the state that was interrupted (RUNNING/WAITING)."""
        target = self._paused_from or ProcessState.RUNNING
        if target not in (ProcessState.RUNNING, ProcessState.WAITING):
            target = ProcessState.RUNNING
        self.transition_to(target)
        return target
