"""WorkChain (paper §II.B.3): checkpointable multi-step workflows.

The outline DSL (``while_``, ``if_``/``elif_``/``else_``, ``return_``)
compiles to a tree of *steppers*, each of which can serialize its exact
position — so a work chain interrupted between steps (crash, restart,
pause) resumes from the last completed step with its context intact.

Between every step the engine checkpoints (context + stepper position) and
yields the event loop. Steps that submit subprocesses return ``ToContext``
awaitables; the chain transitions to WAITING until the children broadcast
termination (paper §III.C.c), then continues with the child nodes bound
into its context.
"""

from __future__ import annotations

import asyncio
from typing import Any, Callable, Mapping

from repro.core.datatypes import DataValue
from repro.core.exit_code import ExitCode
from repro.core.process import Process, ProcessState
from repro.observability import trace
from repro.provenance.store import NodeType


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

class AttributeDict(dict):
    """The work chain context: a dict with attribute access (self.ctx.n)."""

    def __getattr__(self, k):
        try:
            return self[k]
        except KeyError as exc:
            raise AttributeError(k) from exc

    def __setattr__(self, k, v):
        self[k] = v

    def __delattr__(self, k):
        del self[k]


class ProcessNodeView:
    """A finished subprocess as seen from a parent's context."""

    def __init__(self, store, pk: int):
        self._store = store
        self.pk = pk

    @property
    def _node(self) -> dict:
        from repro.provenance.store import SUMMARY_COLUMNS
        return self._store.get_node(self.pk, columns=SUMMARY_COLUMNS) or {}

    @property
    def process_state(self) -> str:
        return self._node.get("process_state", "")

    @property
    def exit_status(self) -> int | None:
        return self._node.get("exit_status")

    @property
    def is_finished(self) -> bool:
        return self.process_state in ("finished", "excepted", "killed")

    @property
    def is_finished_ok(self) -> bool:
        return self.process_state == "finished" and self.exit_status == 0

    @property
    def outputs(self) -> AttributeDict:
        from repro.provenance.store import LinkType
        out = AttributeDict()
        for out_pk, lt, label in self._store.outgoing(self.pk):
            if lt in (LinkType.CREATE.value, LinkType.RETURN.value):
                parts = label.split("__")
                tgt = out
                for p in parts[:-1]:
                    tgt = tgt.setdefault(p, AttributeDict())
                tgt[parts[-1]] = self._store.load_data(out_pk)
        return out

    def __repr__(self):
        return f"ProcessNodeView(pk={self.pk}, state={self.process_state!r})"


# ---------------------------------------------------------------------------
# ToContext / append_
# ---------------------------------------------------------------------------

class _Append:
    def __init__(self, value):
        self.value = value


def append_(value) -> _Append:
    return _Append(value)


class ToContext(dict):
    """Register submitted subprocesses as awaitables (paper listing 11)."""


# ---------------------------------------------------------------------------
# Outline instructions and steppers
# ---------------------------------------------------------------------------

class _Instruction:
    def create_stepper(self):
        raise NotImplementedError


class _Step(_Instruction):
    def __init__(self, method):
        if not callable(method):
            raise TypeError(f"outline entries must be callables, got {method!r}")
        self.name = method.__name__

    def create_stepper(self):
        return _StepStepper(self)


class _Block(_Instruction):
    def __init__(self, instructions):
        self.body = _build_outline(instructions)

    def create_stepper(self):
        return _SequenceStepper(self.body)


class _While(_Instruction):
    def __init__(self, condition):
        self.cond_name = condition.__name__
        self.body: list[_Instruction] = []

    def __call__(self, *instructions):
        self.body = _build_outline(instructions)
        return self

    def create_stepper(self):
        return _WhileStepper(self)


class _If(_Instruction):
    def __init__(self, condition):
        self.branches: list[tuple[str | None, list[_Instruction]]] = []
        self._pending_cond = condition.__name__

    def __call__(self, *instructions):
        self.branches.append((self._pending_cond, _build_outline(instructions)))
        self._pending_cond = None
        return self

    def elif_(self, condition):
        self._pending_cond = condition.__name__
        return self

    def else_(self, *instructions):
        self.branches.append((None, _build_outline(instructions)))
        return self

    def create_stepper(self):
        return _IfStepper(self)


class _Return(_Instruction):
    def __init__(self, exit_code: ExitCode | int = 0):
        self.exit_code = exit_code

    def __call__(self, exit_code):
        return _Return(exit_code)

    def create_stepper(self):
        return _ReturnStepper(self)


def while_(condition) -> _While:
    return _While(condition)


def if_(condition) -> _If:
    return _If(condition)


return_ = _Return()


def _build_outline(instructions) -> list[_Instruction]:
    out: list[_Instruction] = []
    for ins in instructions:
        if isinstance(ins, _Instruction):
            out.append(ins)
        else:
            out.append(_Step(ins))
    return out


# -- steppers: execute one basic step per call; save/load position ----------

class _StepStepper:
    def __init__(self, step: _Step):
        self.step_def = step
        self.done = False

    def step(self, wc: "WorkChain"):
        method = getattr(wc, self.step_def.name)
        with trace.span("workchain.step", step=self.step_def.name):
            result = method()
        self.done = True
        return True, result

    def save(self):
        return {"t": "step", "done": self.done}

    def load(self, pos):
        self.done = pos.get("done", False)


class _SequenceStepper:
    def __init__(self, body: list[_Instruction]):
        self.body = body
        self.idx = 0
        self.child = None

    def step(self, wc):
        if self.idx >= len(self.body):
            return True, None
        if self.child is None:
            self.child = self.body[self.idx].create_stepper()
        finished, result = self.child.step(wc)
        if finished:
            self.idx += 1
            self.child = None
        return self.idx >= len(self.body), result

    def save(self):
        return {"t": "seq", "idx": self.idx,
                "child": self.child.save() if self.child else None}

    def load(self, pos):
        self.idx = pos["idx"]
        if pos.get("child") is not None and self.idx < len(self.body):
            self.child = self.body[self.idx].create_stepper()
            self.child.load(pos["child"])


class _WhileStepper:
    def __init__(self, ins: _While):
        self.ins = ins
        self.child: _SequenceStepper | None = None
        self.checked = False

    def step(self, wc):
        if self.child is None:
            cond = getattr(wc, self.ins.cond_name)()
            if not cond:
                return True, None
            self.child = _SequenceStepper(self.ins.body)
        finished, result = self.child.step(wc)
        if finished:
            self.child = None   # re-check the condition next step
        # a while-stepper is never finished by its body completing — only
        # by its condition evaluating false at the top of a future step
        return False, result

    def save(self):
        return {"t": "while", "child": self.child.save() if self.child else None}

    def load(self, pos):
        if pos.get("child") is not None:
            self.child = _SequenceStepper(self.ins.body)
            self.child.load(pos["child"])


class _IfStepper:
    def __init__(self, ins: _If):
        self.ins = ins
        self.branch: int | None = None
        self.child: _SequenceStepper | None = None

    def step(self, wc):
        if self.branch is None:
            self.branch = -1
            for i, (cond_name, _body) in enumerate(self.ins.branches):
                if cond_name is None or getattr(wc, cond_name)():
                    self.branch = i
                    break
            if self.branch < 0:
                return True, None
            self.child = _SequenceStepper(self.ins.branches[self.branch][1])
        finished, result = self.child.step(wc)
        return finished, result

    def save(self):
        return {"t": "if", "branch": self.branch,
                "child": self.child.save() if self.child else None}

    def load(self, pos):
        self.branch = pos.get("branch")
        if self.branch is not None and self.branch >= 0 and pos.get("child"):
            self.child = _SequenceStepper(self.ins.branches[self.branch][1])
            self.child.load(pos["child"])


class _ReturnStepper:
    def __init__(self, ins: _Return):
        self.ins = ins

    def step(self, wc):
        ec = self.ins.exit_code
        if isinstance(ec, int) and ec == 0:
            return True, _STOP_OK
        return True, ec

    def save(self):
        return {"t": "return"}

    def load(self, pos):
        pass


class _StopOK:
    """Sentinel: outline return_ with status 0 — finish early, success."""


_STOP_OK = _StopOK()


# ---------------------------------------------------------------------------
# The WorkChain itself
# ---------------------------------------------------------------------------

class Awaitable:
    def __init__(self, key: str, pk: int, append: bool):
        self.key = key
        self.pk = pk
        self.append = append


class WorkChain(Process):
    NODE_TYPE = NodeType.WORK_CHAIN

    def __init__(self, inputs=None, **kw):
        # chain state must exist before Process.__init__ writes the initial
        # checkpoint (checkpoint_extras() reads ctx/stepper/awaitables) —
        # without it a freshly-created chain cannot be shipped to a daemon
        # worker, which resumes purely from the persisted checkpoint
        self.ctx = AttributeDict()
        self._awaitables: list[Awaitable] = []
        self._stepper = None
        super().__init__(inputs, **kw)

    # -- submitting children (paper §II.B.3.d) ----------------------------------
    def submit(self, process_class, **inputs):
        """Submit a child process; accepts a Process class (with keyword
        inputs) or a ProcessBuilder, like the engine/launch.py free
        functions."""
        return self.runner.submit(process_class, inputs=inputs,
                                  parent_pk=self.pk)

    def to_context(self, **kwargs) -> None:
        for key, value in kwargs.items():
            if isinstance(value, _Append):
                self._awaitables.append(Awaitable(key, value.value.pk, True))
            else:
                self._awaitables.append(Awaitable(key, value.pk, False))

    # -- driver ---------------------------------------------------------------------
    async def run(self):
        outline = self.spec().get_outline()
        if outline is None:
            raise RuntimeError(
                f"{type(self).__name__} defines no outline")
        if self._stepper is None:
            self._stepper = _SequenceStepper(outline)
        # resuming with awaitables pending? resolve them first
        if self._awaitables:
            await self._resolve_awaitables()

        while True:
            await self._pause_point()
            # the transition between steps yields the interpreter so other
            # processes on this runner make progress (paper §II.B.3)
            await asyncio.sleep(0)
            finished, result = self._stepper.step(self)

            if isinstance(result, _StopOK):
                return None
            if isinstance(result, ExitCode):
                return result
            if isinstance(result, int) and result != 0:
                return result
            if isinstance(result, ToContext):
                self.to_context(**result)
            if self._awaitables:
                self.transition_to(ProcessState.WAITING)
                await self._resolve_awaitables()
                if not self.is_terminated:
                    self.transition_to(ProcessState.RUNNING)
            else:
                # checkpoint between steps (engine guarantee, §II.B.3):
                # marked dirty here, flushed in ONE transaction at the
                # pause point above — always before the next step runs
                self._ckpt_dirty = True
            if finished:
                return None

    async def _resolve_awaitables(self) -> None:
        pending = list(self._awaitables)
        self._awaitables.clear()
        # one event-driven wait per child, all concurrent: the chain wakes
        # when the LAST terminal broadcast arrives, not after a poll sweep
        await self.interruptible(
            self.runner.wait_all([aw.pk for aw in pending]))
        for aw in pending:
            view = ProcessNodeView(self.store, aw.pk)
            if aw.append:
                self.ctx.setdefault(aw.key, []).append(view)
            else:
                self.ctx[aw.key] = view

    # -- exposed inputs helper (paper listing 16) ----------------------------------
    def exposed_inputs(self, process_class, namespace: str | None = None
                       ) -> dict:
        names = self.spec().exposed_input_names(process_class, namespace)
        source = (self.inputs.get(namespace, {}) if namespace
                  else self.inputs)
        return {k: source[k] for k in names if k in source}

    # -- checkpoint integration --------------------------------------------------------
    def checkpoint_extras(self) -> dict:
        return {
            "ctx": _serialize_ctx(self.ctx),
            "stepper": self._stepper.save() if self._stepper else None,
            "awaitables": [(a.key, a.pk, a.append) for a in self._awaitables],
        }

    def load_checkpoint_extras(self, extras: dict) -> None:
        self.ctx = _deserialize_ctx(extras.get("ctx", {}), self.store)
        self._awaitables = [Awaitable(k, pk, ap)
                            for k, pk, ap in extras.get("awaitables", [])]
        outline = self.spec().get_outline()
        self._stepper = _SequenceStepper(outline)
        if extras.get("stepper") is not None:
            self._stepper.load(extras["stepper"])


def _serialize_ctx(ctx: Mapping[str, Any]) -> dict:
    out = {}
    for k, v in ctx.items():
        if isinstance(v, ProcessNodeView):
            out[k] = {"__node__": v.pk}
        elif isinstance(v, DataValue) and v.is_stored:
            # by reference: per-step checkpoints stop copying payloads
            out[k] = {"__data_ref__": v.pk}
        elif isinstance(v, DataValue):
            out[k] = {"__data__": v.to_payload(), "pk": v.pk}
        elif isinstance(v, list) and all(
                isinstance(e, ProcessNodeView) for e in v):
            out[k] = {"__nodes__": [e.pk for e in v]}
        elif isinstance(v, Mapping):
            out[k] = {"__ns__": _serialize_ctx(v)}
        else:
            out[k] = {"__raw__": v}
    return out


def _deserialize_ctx(payload: dict, store) -> AttributeDict:
    ctx = AttributeDict()
    for k, entry in payload.items():
        if "__node__" in entry:
            ctx[k] = ProcessNodeView(store, entry["__node__"])
        elif "__nodes__" in entry:
            ctx[k] = [ProcessNodeView(store, pk) for pk in entry["__nodes__"]]
        elif "__data_ref__" in entry:
            ctx[k] = store.load_data(entry["__data_ref__"])
        elif "__data__" in entry:
            dv = DataValue.from_payload(entry["__data__"])
            dv.pk = entry.get("pk")
            ctx[k] = dv
        elif "__ns__" in entry:
            ctx[k] = _deserialize_ctx(entry["__ns__"], store)
        else:
            ctx[k] = entry.get("__raw__")
    return ctx
