"""The Process class (paper §II.A, §III.B).

Any entity the engine can run. Combines:

* the declarative ProcessSpec (ports, exit codes),
* the extended state machine (CREATED → RUNNING → WAITING → … fig. 6),
* provenance integration (a process node is created on instantiation,
  inputs are linked on creation, outputs on termination),
* checkpoint persistence at every state transition (fig. 7),
* external control (pause / play / kill) via interruptible waits,
* broadcast of state changes so parents can resume on child termination.
"""

from __future__ import annotations

import asyncio
import contextvars
import json
import time
import traceback
from typing import Any, Mapping

from repro.chaos import faults as chaos
from repro.core.datatypes import DataValue, to_data_value
from repro.core.exit_code import ExitCode
from repro.core.ports import PortNamespace
from repro.core.process_spec import ProcessSpec
from repro.core.statemachine import ProcessState, StateMachine
from repro.observability import metrics as _metrics
from repro.observability import trace
from repro.observability.timeline import (
    STATE_HISTORY_ATTR, TRACE_LEVELNAME, serialize_spans,
)
from repro.provenance.store import LinkType, NodeType, StaleEpochError

# The process currently executing in this task — used to attach CALL links
# for synchronously-nested process functions (paper fig. 2).
CURRENT_PROCESS: contextvars.ContextVar["Process | None"] = \
    contextvars.ContextVar("CURRENT_PROCESS", default=None)

_INPUT_LINK = {
    NodeType.CALC_FUNCTION: LinkType.INPUT_CALC,
    NodeType.CALC_JOB: LinkType.INPUT_CALC,
    NodeType.WORK_FUNCTION: LinkType.INPUT_WORK,
    NodeType.WORK_CHAIN: LinkType.INPUT_WORK,
    NodeType.PROCESS: LinkType.INPUT_WORK,
}
_OUTPUT_LINK = {
    NodeType.CALC_FUNCTION: LinkType.CREATE,
    NodeType.CALC_JOB: LinkType.CREATE,
    NodeType.WORK_FUNCTION: LinkType.RETURN,
    NodeType.WORK_CHAIN: LinkType.RETURN,
    NodeType.PROCESS: LinkType.RETURN,
}
_CALL_LINK = {
    NodeType.CALC_FUNCTION: LinkType.CALL_CALC,
    NodeType.CALC_JOB: LinkType.CALL_CALC,
    NodeType.WORK_FUNCTION: LinkType.CALL_WORK,
    NodeType.WORK_CHAIN: LinkType.CALL_WORK,
    NodeType.PROCESS: LinkType.CALL_WORK,
}


class ProcessKilled(Exception):
    pass


class Process(StateMachine):
    NODE_TYPE: NodeType = NodeType.PROCESS
    # caching (AiiDA 1.0 §caching): bump CACHE_VERSION to invalidate every
    # cached result of a class after a behaviour change; CACHEABLE=None
    # derives eligibility from the node type (calc-like yes, work-like no)
    CACHE_VERSION: int = 1
    CACHEABLE: bool | None = None
    _spec_cache: dict[type, ProcessSpec] = {}

    # -- specification ---------------------------------------------------------
    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        """Subclasses extend; must call super().define(spec)."""

    @classmethod
    def spec(cls) -> ProcessSpec:
        if cls not in Process._spec_cache:
            spec = ProcessSpec()
            cls.define(spec)
            Process._spec_cache[cls] = spec
        return Process._spec_cache[cls]

    @classmethod
    def get_builder(cls):
        """A ProcessBuilder over this class's input ports: tab-completable
        namespace attribute access, per-assignment validation and raw-value
        serialization (paper §II.A; launch it via engine/launch.py)."""
        from repro.core.builder import ProcessBuilder
        return ProcessBuilder(cls)

    # -- construction ------------------------------------------------------------
    def __init__(self, inputs: Mapping[str, Any] | None = None, *,
                 runner=None, parent_pk: int | None = None):
        super().__init__()
        from repro.engine.runner import default_runner
        self.runner = runner or default_runner()
        self.store = self.runner.store
        spec = self.spec()

        # serialize (raw python → DataValue through port serializers) first,
        # so defaults — including callable ones evaluated per-instantiation —
        # and caller values are wrapped before validation and fingerprinting
        merged = _merge_defaults(spec.inputs, dict(inputs or {}))
        merged = spec.inputs.serialize(merged)
        err = spec.validate_inputs(merged)
        if err is not None:
            raise ValueError(f"invalid inputs for {type(self).__name__}: {err}")
        self.inputs = merged
        self.metadata = dict(merged.get("metadata") or {})

        self.outputs: dict[str, Any] = {}
        self._exit_code: ExitCode | None = None
        self._killed_msg: str | None = None
        self._done = asyncio.Event()
        self._play = asyncio.Event()
        self._play.set()
        self._interrupts: list[asyncio.Future] = []
        self._pause_requested = False
        # write coalescing (unit of work): state/attribute updates and
        # checkpoint writes buffer here and flush in ONE store transaction
        # at the next flush boundary (pause point, interruptible await,
        # long-lived state, termination) — ~2 commits per process instead
        # of one commit per store call
        self._pending_update: dict | None = None
        self._ckpt_dirty = False
        self._last_ckpt_json: str | None = None
        # lease epoch (fencing token): set when this instance was handed
        # its pk by the broker; every flush/terminal transaction asserts
        # it against the store so a stale holder cannot write (§III.C)
        self._epoch: int | None = None
        # per-state dwell times ([state, wall-ts] per transition) — rides
        # the existing attribute writes, no extra commits
        self._state_history: list[list] = []
        self._timeline = None

        # input fingerprint — computed for every cacheable type regardless
        # of the current policy (so any later run can reuse this node);
        # never-cacheable types (workchains …) skip the O(bytes) digest
        self._input_hash: str | None = None
        try:
            from repro.caching.config import _is_cacheable
            from repro.caching.hashing import compute_input_hash
            if _is_cacheable(type(self)):
                self._input_hash = compute_input_hash(type(self), merged,
                                                      ns=spec.inputs)
        except Exception:  # noqa: BLE001 — hashing must never block creation
            pass

        # provenance node + input links + initial checkpoint, atomically:
        # one commit for the whole creation instead of one per input
        parent = CURRENT_PROCESS.get()
        if parent_pk is None and parent is not None:
            parent_pk = parent.pk
        with self.store.transaction():
            self.pk = self.store.create_process_node(
                self.NODE_TYPE, process_type=type(self).__name__,
                label=self.metadata.get("label", ""),
                description=self.metadata.get("description", ""),
                node_hash=self._input_hash)
            self._link_inputs(spec.inputs, merged, prefix="")
            if parent_pk is not None:
                self.store.add_link(parent_pk, self.pk,
                                    _CALL_LINK[self.NODE_TYPE],
                                    f"CALL_{self.pk}")
            self.parent_pk = parent_pk
            # initial checkpoint: a freshly-created process can be shipped
            # to a daemon worker (task queue carries only the pk; §III.C.a)
            try:
                self._write_checkpoint()
            except Exception:  # noqa: BLE001
                pass

    def _link_inputs(self, ns: PortNamespace, values: Mapping[str, Any],
                     prefix: str) -> None:
        pairs: list[tuple[DataValue, str]] = []
        self._collect_input_links(ns, values, prefix, pairs)
        if not pairs:
            return
        link_type = _INPUT_LINK[self.NODE_TYPE]
        self.store.store_data_many([dv for dv, _label in pairs])
        self.store.add_links([(dv.pk, self.pk, link_type, label)
                              for dv, label in pairs])

    def _collect_input_links(self, ns: PortNamespace,
                             values: Mapping[str, Any], prefix: str,
                             pairs: list[tuple[DataValue, str]]) -> None:
        for key, value in values.items():
            port = ns.get(key)
            label = f"{prefix}{key}"
            if port is not None and port.non_db:
                continue
            if isinstance(port, PortNamespace) and isinstance(value, Mapping):
                self._collect_input_links(port, value, f"{label}__", pairs)
                continue
            if isinstance(value, DataValue):
                pairs.append((value, label))
            elif isinstance(value, Mapping) and (
                    port is None or getattr(port, "dynamic", False)):
                for k2, v2 in value.items():
                    if isinstance(v2, DataValue):
                        pairs.append((v2, f"{label}__{k2}"))

    # -- identity ------------------------------------------------------------------
    @property
    def pid(self) -> int:
        return self.pk

    @property
    def exit_code(self) -> ExitCode | None:
        return self._exit_code

    @property
    def exit_codes(self):
        return self.spec().exit_codes

    @property
    def is_finished_ok(self) -> bool:
        return (self.state is ProcessState.FINISHED and
                self._exit_code is not None and
                self._exit_code.is_finished_ok)

    # -- reporting (paper §II.B.3.b) -------------------------------------------------
    def report(self, msg: str, *args) -> None:
        message = msg % args if args else msg
        self.store.add_log(self.pk, "REPORT", message)
        self.runner.logger.info("[%s|%d] %s", type(self).__name__, self.pk,
                                message)

    # -- outputs (paper §II.B.3.e) ------------------------------------------------------
    def out(self, label: str, value: Any) -> None:
        """Record an output in memory; committed at step/termination."""
        self.outputs[label] = value

    def _commit_outputs(self) -> str | None:
        """Validate + store outputs, link them (bulk). Returns error or
        None."""
        err = self.spec().validate_outputs(self.outputs)
        if err is not None:
            return err
        link_type = _OUTPUT_LINK[self.NODE_TYPE]
        pairs: list[tuple[DataValue, str]] = []
        for label, value in self.outputs.items():
            if isinstance(value, Mapping) and not isinstance(value, DataValue):
                for k2, v2 in value.items():
                    pairs.append((to_data_value(v2), f"{label}__{k2}"))
                continue
            pairs.append((to_data_value(value), label))
        if pairs:
            self.store.store_data_many([dv for dv, _label in pairs])
            self.store.add_links([(self.pk, dv.pk, link_type, label)
                                  for dv, label in pairs])
        return None

    # -- provenance write coalescing (unit of work) ---------------------------
    def _merge_pending(self, update: dict) -> None:
        if self._pending_update is None:
            self._pending_update = dict(update)
            return
        attrs = dict(self._pending_update.get("attributes") or {})
        attrs.update(update.get("attributes") or {})
        self._pending_update.update(update)
        self._pending_update["attributes"] = attrs

    def stash_attributes(self, attrs: dict) -> None:
        """Record node attributes without an immediate commit; they land
        with the step's transaction at the next flush boundary."""
        self._merge_pending({"attributes": dict(attrs)})

    def _write_checkpoint(self) -> None:
        """Serialize + persist the checkpoint, skipping the write when it
        is byte-identical to the last one (the dirty check)."""
        js = json.dumps(self.get_checkpoint())
        if js != self._last_ckpt_json:
            self.store.save_checkpoint(self.pk, js)
            self._last_ckpt_json = js

    def _flush_provenance(self) -> None:
        """Write buffered state updates + the checkpoint in one store
        transaction. Called at every suspension point the engine controls,
        so durability is guaranteed before the process can lose the CPU."""
        if self._pending_update is None and not self._ckpt_dirty:
            return
        # the engine-step-vs-store-flush seam: between here and the commit
        # the step exists only in memory — a crash must roll the process
        # back to its previous durable checkpoint, losing work but never
        # correctness
        chaos.fault_point("process.flush.pre", pk=self.pk)
        with trace.span("checkpoint.flush"), self.store.transaction():
            self.store.fence_epoch(self.pk, self._epoch)
            if self._pending_update is not None:
                update, self._pending_update = self._pending_update, None
                self.store.update_process(self.pk, **update)
            if self._ckpt_dirty and not self.state.is_terminal:
                try:
                    self._write_checkpoint()
                except Exception:  # noqa: BLE001 — must not kill the run
                    self.runner.logger.exception(
                        "checkpoint failed for %d", self.pk)
        self._ckpt_dirty = False
        # flush durable, process about to continue — the other edge of
        # the seam (a crash here redelivers an up-to-date checkpoint)
        chaos.fault_point("process.flush.post", pk=self.pk)

    def checkpoint_now(self) -> None:
        """Force a durable checkpoint immediately (stage boundaries in
        CalcJob), folded into one transaction with any buffered update."""
        self._ckpt_dirty = True
        self._flush_provenance()

    # -- state machine hooks -------------------------------------------------------------
    def on_entered(self, from_state: ProcessState) -> None:
        state = self.state
        self._state_history.append([state.value, time.time()])
        self._merge_pending({
            "state": state.value,
            "exit_status": (self._exit_code.status
                            if self._exit_code else None),
            "exit_message": (self._exit_code.message
                             if self._exit_code else None),
            "attributes": {"paused": state is ProcessState.PAUSED,
                           STATE_HISTORY_ATTR: self._state_history}})
        if state.is_terminal:
            # the terminal write is one transaction: final state +
            # buffered attributes + checkpoint removal (joins the caller's
            # step transaction when there is one)
            with self.store.transaction():
                self.store.fence_epoch(self.pk, self._epoch)
                update, self._pending_update = self._pending_update, None
                self.store.update_process(self.pk, **update)
                self.store.delete_checkpoint(self.pk)
            self._ckpt_dirty = False
            self._done.set()
        elif state is ProcessState.RUNNING:
            # short transit state: coalesce into the step's transaction at
            # the next flush boundary (pause point / interruptible await /
            # terminal transition)
            self._ckpt_dirty = True
        else:
            # WAITING / PAUSED are long-lived and externally observable:
            # make them (and their checkpoint) durable right away
            self._ckpt_dirty = True
            self._flush_provenance()
        comm = getattr(self.runner, "communicator", None)
        if comm is not None:
            from repro.engine.communicator import state_subject
            body = {"pk": self.pk,
                    "from": from_state.value,
                    "state": state.value,
                    "exit_status": (self._exit_code.status
                                    if self._exit_code else None),
                    "ts": time.time()}
            # never broadcast ahead of durability: a waiter in another OS
            # process reads the store the moment this lands — when the
            # terminal transition sits inside a step transaction, the
            # broadcast is deferred until that transaction commits
            self.store.after_commit(lambda: comm.broadcast_send(
                subject=state_subject(self.pk, state.value),
                sender=self.pk, body=body))

    # -- checkpointing (paper §III.B.1, fig. 7) ---------------------------------------------
    def get_checkpoint(self) -> dict:
        """Serialize enough state to recreate this process ('out_state')."""
        return {
            "process_class": f"{type(self).__module__}:{type(self).__qualname__}",
            "pk": self.pk,
            "state": self.state.value,
            "inputs": _serialize_inputs(self.spec().inputs, self.inputs),
            "parent_pk": self.parent_pk,
            "extras": self.checkpoint_extras(),
        }

    def checkpoint_extras(self) -> dict:
        """Subclass hook (workchain ctx, calcjob stage, …)."""
        return {}

    def load_checkpoint_extras(self, extras: dict) -> None:  # noqa: B027
        pass

    @classmethod
    def recreate_from_checkpoint(cls, checkpoint: dict, runner=None,
                                 epoch: int | None = None) -> "Process":
        import importlib

        mod_name, _, qual = checkpoint["process_class"].partition(":")
        mod = importlib.import_module(mod_name)
        klass = mod
        for part in qual.split("."):
            klass = getattr(klass, part)
        self = object.__new__(klass)  # bypass __init__ node creation
        StateMachine.__init__(self)
        from repro.engine.runner import default_runner
        self.runner = runner or default_runner()
        self.store = self.runner.store
        self.inputs = _deserialize_inputs(checkpoint["inputs"], self.store)
        self.metadata = dict(self.inputs.get("metadata") or {})
        self.outputs = {}
        self._exit_code = None
        self._killed_msg = None
        self._done = asyncio.Event()
        self._play = asyncio.Event()
        self._play.set()
        self._interrupts = []
        self._pause_requested = False
        self._pending_update = None
        self._ckpt_dirty = False
        self._last_ckpt_json = None
        self._epoch = epoch
        self._timeline = None
        self.pk = checkpoint["pk"]
        self.parent_pk = checkpoint.get("parent_pk")
        node = self.store.get_node(
            self.pk, columns=("node_hash", "attributes")) or {}
        self._input_hash = node.get("node_hash")
        # continue the recorded dwell history across worker hand-offs
        try:
            attrs = json.loads(node.get("attributes") or "{}")
            self._state_history = list(attrs.get(STATE_HISTORY_ATTR) or [])
        except ValueError:
            self._state_history = []
        self.load_checkpoint_extras(checkpoint.get("extras", {}))
        return self

    # -- external control (paper §III.C RPC) ---------------------------------------------------
    def _control_handler(self, msg: dict) -> Any:
        """The per-process RPC endpoint: any client reaching
        ``process.<pk>`` (directly or forwarded through the broker) drives
        this process with an intent message."""
        from repro.engine.communicator import CONTROL_INTENTS, control_intent
        intent = control_intent(msg)
        if intent == "pause":
            self.pause()
            return True
        if intent == "play":
            self.play()
            return True
        if intent == "kill":
            message = msg.get("message", "killed via RPC")
            # durable first: should this worker die before the in-memory
            # kill lands, no restarted worker may resurrect the process
            try:
                self.store.update_process(
                    self.pk, attributes={"kill_requested": message})
            except Exception:  # noqa: BLE001 — still honour the live kill
                pass
            self.kill(message)
            return True
        if intent == "status":
            return {"pk": self.pk, "state": self.state.value,
                    "paused": self.state is ProcessState.PAUSED,
                    "exit_status": (self._exit_code.status
                                    if self._exit_code else None)}
        raise ValueError(f"unknown control intent {intent!r}; "
                         f"expected one of {CONTROL_INTENTS}")

    def _register_control(self) -> None:
        comm = getattr(self.runner, "communicator", None)
        if comm is not None:
            from repro.engine.communicator import process_rpc_id
            comm.add_rpc_subscriber(process_rpc_id(self.pk),
                                    self._control_handler)

    def _unregister_control(self) -> None:
        comm = getattr(self.runner, "communicator", None)
        if comm is not None:
            from repro.engine.communicator import process_rpc_id
            comm.remove_rpc_subscriber(process_rpc_id(self.pk))

    def _kill_requested_durably(self) -> str | None:
        """A kill recorded in the store by a control client — honoured on
        (re)start so a kill survives worker crashes and restarts."""
        try:
            node = self.store.get_node(self.pk,
                                       columns=("attributes",)) or {}
            attrs = json.loads(node.get("attributes") or "{}")
            return attrs.get("kill_requested")
        except Exception:  # noqa: BLE001
            return None

    def pause(self) -> None:
        self._pause_requested = True
        self._play.clear()

    def play(self) -> None:
        self._pause_requested = False
        if self.state is ProcessState.PAUSED:
            self.resume_from_pause()
        self._play.set()

    def kill(self, msg: str = "killed by user") -> None:
        if self.is_terminated:
            return
        self._killed_msg = msg
        for fut in list(self._interrupts):
            if not fut.done():
                fut.set_exception(ProcessKilled(msg))
        self._play.set()

    async def _pause_point(self) -> None:
        """Honour pause and kill requests between steps; blocks while
        paused. Under a daemon worker (distributed runner) also re-reads
        the durable ``kill_requested`` marker, so a kill recorded while
        this worker was racing to pick the process up (live RPC not yet
        routable) still lands at the next step boundary rather than only
        after a worker restart. Local runs skip the per-step store read —
        their control RPCs arrive in-memory."""
        self._flush_provenance()
        if self._killed_msg is None and \
                getattr(self.runner, "distributed", False):
            self._killed_msg = self._kill_requested_durably()
        if self._killed_msg is not None:
            raise ProcessKilled(self._killed_msg)
        if self._pause_requested and not self.state.is_terminal:
            self.transition_to(ProcessState.PAUSED)
            await self._play.wait()
            if self._killed_msg is not None:
                raise ProcessKilled(self._killed_msg)
            # resume_from_pause() happened in play()

    async def interruptible(self, coro_or_future):
        """Await something, but let kill() break in. Buffered provenance
        writes flush first — this coroutine is about to lose the CPU for
        an unbounded time, so its state must be durable."""
        self._flush_provenance()
        loop = asyncio.get_running_loop()
        interrupt = loop.create_future()
        self._interrupts.append(interrupt)
        try:
            task = asyncio.ensure_future(coro_or_future)
            done, _ = await asyncio.wait(
                {task, interrupt}, return_when=asyncio.FIRST_COMPLETED)
            if interrupt in done:
                task.cancel()
                interrupt.result()  # raises ProcessKilled
            return task.result()
        finally:
            self._interrupts.remove(interrupt)
            if not interrupt.done():
                interrupt.cancel()

    # -- execution driver -----------------------------------------------------------------------
    async def run(self) -> ExitCode | int | None:
        """Subclasses implement the body."""
        raise NotImplementedError

    # -- caching fast path (AiiDA 1.0 §caching) -------------------------------
    def _maybe_use_cache(self) -> ExitCode | None:
        """Consult the cache; on a hit clone the cached outputs onto this
        node and return the cached exit code, else None. Skipping run()
        entirely means a CalcJob never even submits to the scheduler."""
        if self._input_hash is None:
            return None
        try:
            from repro.caching.config import is_caching_enabled_for
            from repro.caching.registry import CacheRegistry
            if not is_caching_enabled_for(type(self)):
                return None
            with trace.span("cache.lookup", pk=self.pk):
                hit = CacheRegistry(self.store).find_cached(
                    type(self).__name__, self._input_hash,
                    exclude_pk=self.pk)
            if hit is None:
                _metrics.get_registry().counter("cache.misses").inc()
                return None
            # phase 1, read-only: rehydrate every output before touching
            # the graph, so a bad source leaves no partial clone behind
            clones = [(label, link_type,
                       DataValue.from_payload(
                           self.store.load_data(data_pk).to_payload()))
                      for label, link_type, data_pk in hit.outputs]
            src_attrs = json.loads(
                (self.store.get_node(hit.pk, columns=("attributes",)) or {})
                .get("attributes") or "{}")
        except Exception:  # noqa: BLE001 — a broken cache must not break runs
            self.store.add_log(self.pk, "WARNING",
                               "cache lookup failed:\n" +
                               traceback.format_exc())
            return None
        try:
            # phase 2: commit the clones — one transaction, bulk writes
            out_ports = self.spec().outputs
            with self.store.transaction():
                self.store.fence_epoch(self.pk, self._epoch)
                self.store.store_data_many(
                    [clone for _l, _lt, clone in clones])
                self.store.add_links(
                    [(self.pk, clone.pk, LinkType(link_type), label)
                     for label, link_type, clone in clones])
                for label, _link_type, clone in clones:
                    # re-nest '<port>__<key>' labels, but only when the
                    # prefix is a declared output port — a flat label that
                    # merely contains '__' stays flat, matching the
                    # cold-run shape
                    ns_label, sep, sub = label.partition("__")
                    if sep and out_ports.get(label) is None and \
                            out_ports.get(ns_label) is not None:
                        self.outputs.setdefault(ns_label, {})[sub] = clone
                    else:
                        self.outputs[label] = clone
                # honest provenance: carry over the source's attributes
                # and advertise what this node was cloned from
                attrs = {k: v for k, v in src_attrs.items()
                         if k not in ("paused", "cached_from",
                                      "cached_from_pk", "kill_requested")}
                attrs.update(cached_from=hit.uuid, cached_from_pk=hit.pk)
                self.store.update_process(self.pk, attributes=attrs)
                self.report("cache hit: cloned %d output(s) from %s<%d>",
                            len(hit.outputs), type(self).__name__, hit.pk)
            _metrics.get_registry().counter("cache.hits").inc()
            return ExitCode(hit.exit_status, hit.exit_message or "",
                            "SUCCESS")
        except StaleEpochError:
            raise  # fenced: the abandon path owns this, not "recompute"
        except Exception:  # noqa: BLE001 — txn already rolled the clones
            # back (links, nodes, attribute writes); only the in-memory
            # output dict needs clearing before run() starts clean
            self.outputs.clear()
            self.store.add_log(self.pk, "WARNING",
                               "cache clone failed; recomputing:\n" +
                               traceback.format_exc())
            return None

    def _persist_timeline(self) -> None:
        """Drain this run's span timeline into ONE TRACE log row. Called
        inside the terminal transaction, so the timeline rides the
        existing unit of work (no extra commit per process)."""
        sink, self._timeline = self._timeline, None
        if sink is None:
            return
        try:
            spans = sink.drain(stamp_open=True)
            if spans:
                self.store.add_logs([(self.pk, TRACE_LEVELNAME,
                                      serialize_spans(spans), time.time())])
        except Exception:  # noqa: BLE001 — telemetry must not kill the run
            self.runner.logger.exception(
                "timeline persistence failed for %d", self.pk)

    def _fenced_abandon(self) -> None:
        """A store transaction was rejected for carrying a stale lease
        epoch: this instance is a zombie (its pk was requeued and is now
        owned — at a higher epoch — by another worker). Abandon cleanly:
        no node write, no state transition, just bump the durable
        ``lease.fenced_writes`` counter the chaos judge asserts on and
        release local waiters. The authoritative run elsewhere produces
        the one true set of outputs."""
        self.runner.logger.warning(
            "process %d fenced at epoch %s: a newer lease holder owns it; "
            "abandoning without writing", self.pk, self._epoch)
        try:
            self.store.incr_meta("lease.fenced_writes")
        except Exception:  # noqa: BLE001 — bookkeeping must not raise here
            pass
        _metrics.get_registry().counter("lease.fenced_writes").inc()
        self._exit_code = ExitCode(
            997, "stale lease epoch; another worker owns this process",
            "FENCED")
        self._done.set()

    async def step_until_terminated(self) -> ExitCode:
        token = CURRENT_PROCESS.set(self)
        # the whole run is one root span; sub-steps (state transitions,
        # cache lookup, checkpoint flushes, workchain steps) nest under
        # it and the drained tree persists with the terminal transaction
        self._timeline = trace.start_timeline()
        sink_token = (trace.push_sink(self._timeline)
                      if self._timeline is not None else None)
        root = trace.span("process.run", pk=self.pk,
                          process=type(self).__name__)
        root.__enter__()
        # every live process is reachable over RPC for its whole run —
        # regardless of which runner/worker drives it (paper §III.C.b)
        self._register_control()
        try:
            # a kill recorded durably while no worker owned this process
            # is applied before any work — no resurrection after restart
            killed = self._kill_requested_durably()
            if killed is not None:
                raise ProcessKilled(killed)
            await self._pause_point()
            self.transition_to(ProcessState.RUNNING)
            exit_code = self._maybe_use_cache()
            if exit_code is None:
                with trace.span("process.body"):
                    result = await self.run()
                exit_code = _interpret_result(result)
                # body done, terminal unit of work not started: a crash
                # here reruns the process from its last checkpoint — the
                # invariant checker proves outputs still land exactly once
                chaos.fault_point("process.terminal.pre", pk=self.pk)
                # the terminal step is one unit of work: output storing +
                # links + final state + checkpoint removal + span
                # timeline, one commit
                with self.store.transaction():
                    if exit_code.is_finished_ok:
                        err = self._commit_outputs()
                        if err is not None:
                            exit_code = ExitCode(
                                11, f"output validation failed: {err}",
                                "ERROR_INVALID_OUTPUTS")
                    self._exit_code = exit_code
                    self._persist_timeline()
                    if not self.is_terminated:
                        self.transition_to(ProcessState.FINISHED)
            else:
                self._exit_code = exit_code
                with self.store.transaction():
                    self._persist_timeline()
                    if not self.is_terminated:
                        self.transition_to(ProcessState.FINISHED)
        except StaleEpochError:
            # fencing token rejected: another worker holds a newer lease
            # on this pk. Abandon without writing anything — the new
            # holder's run is the authoritative one (split-brain safety).
            self._fenced_abandon()
        except ProcessKilled as exc:
            self._exit_code = ExitCode(998, str(exc), "KILLED")
            try:
                with self.store.transaction():
                    self._persist_timeline()
                    if not self.is_terminated:
                        self.transition_to(ProcessState.KILLED)
            except StaleEpochError:
                self._fenced_abandon()
        except Exception:  # noqa: BLE001 → EXCEPTED, never propagate
            tb = traceback.format_exc()
            self._exit_code = ExitCode(999, "process excepted", "EXCEPTED")
            try:
                with self.store.transaction():
                    self.store.add_log(self.pk, "ERROR", tb)
                    self._persist_timeline()
                    if not self.is_terminated:
                        self.transition_to(ProcessState.EXCEPTED)
            except StaleEpochError:
                self._fenced_abandon()
        finally:
            self._unregister_control()
            root.__exit__(None, None, None)
            if sink_token is not None:
                trace.pop_sink(sink_token)
            CURRENT_PROCESS.reset(token)
        return self._exit_code

    async def wait_done(self) -> None:
        await self._done.wait()


def _interpret_result(result: Any) -> ExitCode:
    if result is None:
        return ExitCode(0, "", "SUCCESS")
    if isinstance(result, ExitCode):
        return result
    if isinstance(result, int):
        if result < 0:
            raise ValueError("exit status must be non-negative")
        return ExitCode(result, "", "")
    raise TypeError(f"process returned {type(result).__name__}; expected "
                    "None, int or ExitCode")


def _merge_defaults(ns: PortNamespace, values: dict[str, Any]) -> dict[str, Any]:
    out = dict(values)
    for name, port in ns.items():
        if isinstance(port, PortNamespace):
            sub = out.get(name)
            merged = _merge_defaults(port, dict(sub) if sub else {})
            if merged:
                out[name] = merged
        elif name not in out and port.has_default:
            out[name] = port.default
    return out


def _serialize_inputs(ns: PortNamespace, values: Mapping[str, Any]) -> dict:
    out: dict[str, Any] = {}
    for key, value in values.items():
        port = ns.get(key) if ns is not None else None
        if isinstance(value, DataValue):
            if value.is_stored:
                # stored values serialize by reference: checkpoints stop
                # embedding (potentially huge) payload copies — the store
                # (shared by every worker on this profile) rehydrates them
                out[key] = {"__data_ref__": value.pk}
            else:
                out[key] = {"__data__": value.to_payload(), "pk": value.pk}
        elif isinstance(value, Mapping):
            sub_ns = port if isinstance(port, PortNamespace) else None
            out[key] = {"__ns__": _serialize_inputs(sub_ns, value)}
        elif isinstance(value, (str, int, float, bool, type(None))):
            out[key] = {"__raw__": value}
        else:
            out[key] = {"__repr__": repr(value)}
    return out


def _deserialize_inputs(payload: dict, store) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for key, entry in payload.items():
        if "__data_ref__" in entry:
            out[key] = store.load_data(entry["__data_ref__"])
        elif "__data__" in entry:
            dv = DataValue.from_payload(entry["__data__"])
            dv.pk = entry.get("pk")
            out[key] = dv
        elif "__ns__" in entry:
            out[key] = _deserialize_inputs(entry["__ns__"], store)
        elif "__raw__" in entry:
            out[key] = entry["__raw__"]
        else:
            out[key] = entry.get("__repr__")
    return out
