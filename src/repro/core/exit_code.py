"""Exit codes (paper §II.A.3): POSIX-style integer exit statuses with
human-readable labels and messages, declared on the process spec."""

from __future__ import annotations

from typing import NamedTuple


class ExitCode(NamedTuple):
    status: int = 0
    message: str = ""
    label: str = ""

    def format(self, **kwargs) -> "ExitCode":
        return self._replace(message=self.message.format(**kwargs))

    @property
    def is_finished_ok(self) -> bool:
        return self.status == 0


class ExitCodesNamespace(dict):
    """Container allowing attribute access by label:
    ``spec.exit_codes.ERROR_I_AM_A_TEAPOT``."""

    def __getattr__(self, label: str) -> ExitCode:
        try:
            return self[label]
        except KeyError as exc:
            raise AttributeError(
                f"no exit code with label {label!r}; "
                f"available: {sorted(self)}") from exc
