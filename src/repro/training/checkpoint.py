"""Sharded model checkpointing with elastic restore.

Tensor-level fault tolerance, complementing the engine-level process
checkpoints: every leaf is saved as one .npy per addressable shard with a
JSON manifest describing (shape, dtype, shard index map). Restore
reassembles and re-shards onto whatever mesh the restarting job has —
elastic scaling (a 512-chip run can resume on 256, and vice versa).

``AsyncCheckpointer`` overlaps serialization with training (the save runs
on a background thread; the next save barriers on the previous one) — the
standard hide-the-checkpoint-cost trick.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, state: Any,
                    *, max_to_keep: int = 3) -> str:
    """Write state to <directory>/step_<step>/; returns the path."""
    ckpt_dir = os.path.join(directory, f"step_{step}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    manifest: dict[str, Any] = {"step": step, "time": time.time(),
                                "leaves": {}}
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    for path, leaf in flat:
        key = _leaf_key(path)
        safe = key.replace("/", "__")
        arr = leaf
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shards": []}
        if isinstance(arr, jax.Array) and len(arr.addressable_shards) > 1:
            for i, shard in enumerate(arr.addressable_shards):
                fname = f"{safe}.shard{i}.npy"
                np.save(os.path.join(tmp_dir, fname),
                        np.asarray(shard.data))
                entry["shards"].append({
                    "file": fname,
                    "index": [[s.start, s.stop] if s.start is not None
                              else None for s in shard.index],
                })
        else:
            fname = f"{safe}.npy"
            np.save(os.path.join(tmp_dir, fname), np.asarray(arr))
            entry["shards"].append({"file": fname, "index": None})
        manifest["leaves"][key] = entry

    with open(os.path.join(tmp_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    # atomic publish: a crash mid-save never corrupts the latest checkpoint
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.rename(tmp_dir, ckpt_dir)

    _gc_old(directory, max_to_keep)
    return ckpt_dir


def _gc_old(directory: str, max_to_keep: int) -> None:
    steps = sorted(
        (int(d.split("_")[1]), d) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for _, d in steps[:-max_to_keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int | None = None,
                       *, shardings: Any = None, target: Any = None) -> Any:
    """Restore; re-shards onto `shardings` (tree of NamedSharding) if given.

    ``target`` supplies the pytree structure (defaults to manifest order
    reconstructed as a nested dict)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt_dir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as fh:
        manifest = json.load(fh)

    leaves: dict[str, np.ndarray] = {}
    for key, entry in manifest["leaves"].items():
        full = np.zeros(entry["shape"], dtype=entry["dtype"]) \
            if entry["shards"][0]["index"] is not None else None
        for shard in entry["shards"]:
            arr = np.load(os.path.join(ckpt_dir, shard["file"]))
            if shard["index"] is None:
                full = arr
            else:
                idx = tuple(slice(s[0], s[1]) if s is not None else slice(None)
                            for s in shard["index"])
                full[idx] = arr
        leaves[key] = full

    if target is not None:
        flat = jax.tree_util.tree_flatten_with_path(target)
        out_leaves = []
        sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else None)
        for i, (path, _) in enumerate(flat[0]):
            arr = leaves[_leaf_key(path)]
            if sh_flat is not None:
                arr = jax.device_put(arr, sh_flat[i])
            out_leaves.append(arr)
        return jax.tree_util.tree_unflatten(flat[1], out_leaves)

    # nested-dict reconstruction
    root: dict[str, Any] = {}
    for key, arr in leaves.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return root


class AsyncCheckpointer:
    """Overlap checkpoint writes with compute (one in flight at a time)."""

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            self.last_path = save_checkpoint(
                self.directory, step, host_state,
                max_to_keep=self.max_to_keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
