"""Optimizers in pure JAX: AdamW (default) and Adafactor (memory-lean
alternative for the largest models). Both operate on arbitrary pytrees and
inherit the parameter PartitionSpecs, so optimizer state shards exactly like
the parameters (FSDP-compatible)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio. Warmup counts from 1
    so the very first step has a non-zero learning rate."""
    step = step.astype(jnp.float32) + 1.0
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Any) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params)}


def adamw_update(cfg: OptimConfig, grads: Any, opt_state: dict[str, Any],
                 params: Any, step: jax.Array):
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mu_hat = mu_n / bc1
        nu_hat = nu_n / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    out = jax.tree.map(upd, grads, opt_state["mu"], opt_state["nu"], params)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t3: t3[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t3: t3[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t3: t3[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu}, lr


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; for the 314B-class cells)
# ---------------------------------------------------------------------------

def adafactor_init(params: Any) -> dict[str, Any]:
    def row_col(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"fac": jax.tree.map(row_col, params)}


def adafactor_update(cfg: OptimConfig, grads: Any, opt_state: dict[str, Any],
                     params: Any, step: jax.Array):
    lr = lr_schedule(cfg, step)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, st, p):
        g32 = g.astype(jnp.float32)
        sq = jnp.square(g32) + 1e-30
        if p.ndim >= 2:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(sq, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(sq, axis=-2)
            denom = jnp.sqrt(
                vr[..., :, None] * vc[..., None, :] /
                jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30))
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * sq
            denom = jnp.sqrt(v)
            new_st = {"v": v}
        update = g32 / jnp.maximum(denom, 1e-30)
        update = update / jnp.maximum(1.0, global_norm(update) /
                                      (update.size ** 0.5))
        p_n = p.astype(jnp.float32) - lr * (update +
                                            cfg.weight_decay * p.astype(jnp.float32))
        return p_n.astype(p.dtype), new_st

    flat_p, td = jax.tree.flatten(params)
    flat_g = td.flatten_up_to(grads)
    flat_s = td.flatten_up_to(opt_state["fac"])
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = td.unflatten([o[0] for o in outs])
    new_fac = td.unflatten([o[1] for o in outs])
    return new_params, {"fac": new_fac}, lr


def opt_init(cfg: OptimConfig, params: Any) -> dict[str, Any]:
    return adamw_init(params) if cfg.name == "adamw" else adafactor_init(params)


def opt_update(cfg: OptimConfig, grads, opt_state, params, step):
    if cfg.name == "adamw":
        return adamw_update(cfg, grads, opt_state, params, step)
    return adafactor_update(cfg, grads, opt_state, params, step)


def opt_state_axes(cfg: OptimConfig, param_axes: Any) -> dict[str, Any]:
    """Logical axes for optimizer state (mirror params; factored state drops
    the last / second-to-last dim respectively)."""
    if cfg.name == "adamw":
        return {"mu": param_axes, "nu": param_axes}

    def fac_axes(ax):
        if len(ax) >= 2:
            return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2] + ax[-1:])}
        return {"v": tuple(ax)}

    return {"fac": jax.tree.map(
        fac_axes, param_axes,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, str) or e is None for e in x))}
