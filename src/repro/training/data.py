"""Deterministic, checkpointable data pipeline.

Production shape: host-sharded iteration (each data-parallel host consumes
a disjoint stream), exact resume from a serialized cursor, fixed-length
packing of variable-length documents. The token source is synthetic
(seeded Zipf mixture) or a binary token file — the paper's engine treats
it opaquely either way, and provenance records the pipeline state so any
batch can be regenerated.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    source: str = "synthetic"  # synthetic | file
    path: str = ""
    mean_doc_len: int = 200


class TokenStream:
    """Document generator -> packed fixed-length rows with EOD tokens."""

    EOD = 0

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._doc_index = cfg.host_id          # strided host sharding
        self._buffer: list[int] = []
        self._file_tokens: np.ndarray | None = None
        if cfg.source == "file":
            self._file_tokens = np.fromfile(cfg.path, dtype=np.uint16)

    # -- cursor (exact resume) -------------------------------------------------
    def state_dict(self) -> dict:
        return {"doc_index": self._doc_index, "buffer": list(self._buffer)}

    def load_state_dict(self, state: dict) -> None:
        self._doc_index = state["doc_index"]
        self._buffer = list(state["buffer"])

    # -- document source --------------------------------------------------------
    def _doc(self, index: int) -> np.ndarray:
        cfg = self.cfg
        if self._file_tokens is not None:
            n = len(self._file_tokens)
            rng = np.random.default_rng((cfg.seed, index))
            start = int(rng.integers(0, max(1, n - cfg.mean_doc_len)))
            length = int(rng.integers(cfg.mean_doc_len // 2,
                                      cfg.mean_doc_len * 2))
            return self._file_tokens[start:start + length].astype(np.int32)
        rng = np.random.default_rng((cfg.seed, index))
        length = int(rng.integers(cfg.mean_doc_len // 2,
                                  cfg.mean_doc_len * 2))
        # zipf-ish marginal over the vocab, documents correlated by topic
        topic = rng.integers(1, 17)
        toks = (rng.zipf(1.3, size=length) * topic) % (cfg.vocab_size - 1) + 1
        return toks.astype(np.int32)

    def _fill(self, n: int) -> None:
        while len(self._buffer) < n:
            doc = self._doc(self._doc_index)
            self._doc_index += self.cfg.num_hosts
            self._buffer.extend(doc.tolist())
            self._buffer.append(self.EOD)

    def next_batch(self) -> dict[str, np.ndarray]:
        cfg = self.cfg
        need = cfg.batch_size * (cfg.seq_len + 1)
        self._fill(need)
        flat = np.asarray(self._buffer[:need], np.int32)
        self._buffer = self._buffer[need:]
        rows = flat.reshape(cfg.batch_size, cfg.seq_len + 1)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def serialize_state(stream: TokenStream) -> str:
    return json.dumps(stream.state_dict())


def deserialize_state(stream: TokenStream, payload: str) -> None:
    stream.load_state_dict(json.loads(payload))
