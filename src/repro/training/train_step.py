"""The jitted training step: microbatched grad accumulation, clipping,
AdamW/Adafactor update. Works for every architecture family via the
ModelBundle interface and is what the dry-run lowers for ``train_*`` cells."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.registry import ModelBundle
from repro.training import optim as optim_mod
from repro.training.optim import OptimConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: OptimConfig = OptimConfig()
    microbatches: int = 1
    seed: int = 0


def init_train_state(bundle: ModelBundle, tcfg: TrainConfig,
                     rng: jax.Array) -> dict[str, Any]:
    params = bundle.init_params(rng)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": optim_mod.opt_init(tcfg.optim, params),
    }


def train_state_shapes(bundle: ModelBundle, tcfg: TrainConfig) -> dict[str, Any]:
    """ShapeDtypeStruct tree (dry-run; no allocation)."""
    pshapes = bundle.param_shapes()
    opt = jax.eval_shape(
        lambda p: optim_mod.opt_init(tcfg.optim, p), pshapes)
    return {"step": jax.ShapeDtypeStruct((), jnp.int32),
            "params": pshapes, "opt": opt}


def train_state_axes(bundle: ModelBundle, tcfg: TrainConfig) -> dict[str, Any]:
    paxes = bundle.param_axes()
    return {"step": (), "params": paxes,
            "opt": optim_mod.opt_state_axes(tcfg.optim, paxes)}


def _split_microbatches(batch: dict[str, jax.Array], n: int):
    def sp(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(bundle: ModelBundle, tcfg: TrainConfig):
    ocfg = tcfg.optim

    def loss_fn(params, mb):
        loss, metrics = bundle.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict[str, Any], batch: dict[str, Any]):
        params = state["params"]
        n = tcfg.microbatches
        if n > 1:
            mbs = _split_microbatches(batch, n)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n, g_acc, grads)
                return (g_acc, l_acc + loss / n), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_seq = lax.scan(acc_body, (g0, 0.0), mbs)
            metrics = jax.tree.map(lambda m: m[-1], metrics_seq)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, grad_norm = optim_mod.clip_by_global_norm(grads, ocfg.grad_clip)
        new_params, new_opt, lr = optim_mod.opt_update(
            ocfg, grads, state["opt"], params, state["step"])
        new_state = {"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": grad_norm,
            "lr": lr,
            **{k: v.astype(jnp.float32) for k, v in metrics.items()},
        }
        return new_state, out_metrics

    return train_step
