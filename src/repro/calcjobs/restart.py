"""BaseRestartWorkChain: the canonical AiiDA error-handling pattern the
paper motivates (§I: "the problem of error handling when running
high-throughput simulations").

Wraps any subprocess class in a while-loop: run → inspect exit code →
consult registered *process handlers* → retry (possibly with modified
inputs) up to max_iterations. This is what turns the engine's exit-code
machinery into automated fault recovery at scale.
"""

from __future__ import annotations

from typing import Any, Callable

from repro.core.datatypes import Dict, Int
from repro.core.exit_code import ExitCode
from repro.core.process_spec import ProcessSpec
from repro.core.workchain import ToContext, WorkChain, while_


def process_handler(*exit_statuses: int):
    """Decorator marking a method as a handler for given exit statuses."""

    def deco(fn: Callable) -> Callable:
        fn._handler_statuses = exit_statuses
        return fn

    return deco


class HandlerReport:
    def __init__(self, do_break: bool = False,
                 exit_code: ExitCode | None = None):
        self.do_break = do_break
        self.exit_code = exit_code


class BaseRestartWorkChain(WorkChain):
    _process_class: type | None = None

    #: synthetic exit status for a child that died without recording one
    #: (excepted or killed — e.g. its worker was chaos-killed mid-step and
    #: a durable kill landed). Handlers register for it like any real
    #: status, so dead children can be retried instead of read as success.
    EXIT_STATUS_DIED = 999

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("max_iterations", valid_type=Int, default=Int(3))
        spec.inputs.dynamic = True
        spec.outputs.dynamic = True
        spec.exit_code(401, "ERROR_MAXIMUM_ITERATIONS_EXCEEDED",
                       "the maximum number of iterations was exceeded")
        spec.exit_code(402, "ERROR_UNRECOVERABLE_FAILURE",
                       "the subprocess failed with an unhandled exit code")
        spec.outline(
            cls.setup,
            while_(cls.should_run_process)(
                cls.run_process,
                cls.inspect_process,
            ),
            cls.results,
        )

    # -- outline steps ---------------------------------------------------------
    def setup(self) -> None:
        self.ctx.iteration = 0
        self.ctx.is_finished = False
        self.ctx.unhandled = False
        self.ctx.children = []
        self.ctx.process_inputs = {
            k: v for k, v in self.inputs.items()
            if k not in ("metadata", "max_iterations")}

    def should_run_process(self) -> bool:
        return (not self.ctx.is_finished and
                self.ctx.iteration < int(self.inputs["max_iterations"].value))

    def run_process(self):
        self.ctx.iteration += 1
        child = self.submit(self._process_class, **self.ctx.process_inputs)
        self.report("launching %s<%d> (iteration %d)",
                    self._process_class.__name__, child.pk,
                    self.ctx.iteration)
        return ToContext(children=_append(child))

    def inspect_process(self):
        child = self.ctx.children[-1]
        status = child.exit_status or 0
        if status == 0 and child.process_state != "finished":
            # no exit code was ever recorded: the child excepted or was
            # killed — that must not read as success
            status = self.EXIT_STATUS_DIED
        if status == 0:
            self.ctx.is_finished = True
            return None
        for name in dir(type(self)):
            fn = getattr(type(self), name)
            statuses = getattr(fn, "_handler_statuses", None)
            if statuses and status in statuses:
                report = fn(self, child)
                if isinstance(report, HandlerReport):
                    if report.exit_code is not None:
                        return report.exit_code
                    if report.do_break:
                        self.ctx.is_finished = True
                return None
        self.ctx.unhandled = True
        self.report("exit status %d unhandled; giving up", status)
        return self.exit_codes.ERROR_UNRECOVERABLE_FAILURE

    def results(self):
        if not self.ctx.is_finished:
            return self.exit_codes.ERROR_MAXIMUM_ITERATIONS_EXCEEDED
        child = self.ctx.children[-1]
        for label, value in child.outputs.items():
            self.out(label, value)
        return None


def _append(child):
    from repro.core.workchain import append_
    return append_(child)
