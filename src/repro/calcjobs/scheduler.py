"""Scheduler abstraction (paper §II.B.4) + the simulated cluster.

``SimScheduler`` talks to a ``SimulatedCluster`` through transport
commands — exactly the way the SLURM scheduler talks over SSH — so the
whole upload→submit→update→retrieve machinery, the backoff wrapper and the
bundled job manager are exercised end-to-end without real hardware.

``SlurmScheduler`` emits/parses real SLURM commands (deployment path; it is
string-level compatible and unit-tested, the cluster behind it is whatever
the transport connects to).
"""

from __future__ import annotations

import enum
import itertools
import json
import random
import time
from typing import Any, Callable

from repro.engine.transport import LocalTransport, Transport
from repro.observability.metrics import StatsDict


class JobState(str, enum.Enum):
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    UNDETERMINED = "UNDETERMINED"


# ---------------------------------------------------------------------------
# The simulated cluster
# ---------------------------------------------------------------------------

class SimulatedCluster:
    """An in-memory cluster: a queue with configurable delays, runtimes,
    failure injection, and named python executables."""

    def __init__(self, *, queue_delay: float = 0.02, runtime: float = 0.05,
                 fail_rate: float = 0.0, seed: int = 0):
        from concurrent.futures import ThreadPoolExecutor

        self.queue_delay = queue_delay
        self.runtime = runtime
        self.fail_rate = fail_rate
        self.rng = random.Random(seed)
        self.jobs: dict[str, dict[str, Any]] = {}
        self._ids = itertools.count(1000)
        self.executables: dict[str, Callable[[dict], dict]] = {}
        self.filesystems: dict[str, dict[str, bytes]] = {}
        self.stats = StatsDict("scheduler", {"submits": 0, "queries": 0})
        # Executables run OFF the event loop: a worker whose loop is blocked
        # cannot answer broker heartbeats and gets presumed dead — the exact
        # failure mode kiwiPy's separate comm thread exists to prevent
        # (paper §III.C.a).
        self._pool = ThreadPoolExecutor(max_workers=2,
                                        thread_name_prefix="simcluster")

    def register_executable(self, name: str,
                            fn: Callable[[dict], dict]) -> None:
        """fn(input_files: {name: bytes}) -> output_files: {name: bytes}"""
        self.executables[name] = fn

    def make_transport(self, hostname: str = "local") -> LocalTransport:
        t = LocalTransport(hostname)
        t.command_handler = self.handle_command
        t.files = self.filesystems.setdefault(hostname, {})
        return t

    # -- the 'remote side': command handling ---------------------------------
    def handle_command(self, command: str) -> tuple[int, str, str]:
        parts = command.split()
        if parts[0] == "sbatch":
            return self._sbatch(parts[1])
        if parts[0] == "squeue":
            return self._squeue(parts[1].split(",") if len(parts) > 1 else [])
        if parts[0] == "scancel":
            job = self.jobs.get(parts[1])
            if job and job["state"] in (JobState.PENDING, JobState.RUNNING):
                job["state"] = JobState.FAILED
                job["reason"] = "cancelled"
            return 0, "", ""
        return 127, "", f"unknown command: {parts[0]}"

    def _sbatch(self, script_path: str) -> tuple[int, str, str]:
        self.stats["submits"] += 1
        job_id = str(next(self._ids))
        will_fail = self.rng.random() < self.fail_rate
        self.jobs[job_id] = {
            "state": JobState.PENDING,
            "script": script_path,
            "submitted": time.monotonic(),
            "will_fail": will_fail,
            "executed": False,
        }
        return 0, f"Submitted batch job {job_id}", ""

    def _advance(self, job_id: str) -> None:
        job = self.jobs[job_id]
        now = time.monotonic()
        if job["state"] is JobState.PENDING and \
                now - job["submitted"] >= self.queue_delay:
            job["state"] = JobState.RUNNING
            job["started"] = now
        if job["state"] is JobState.RUNNING and \
                now - job["started"] >= self.runtime:
            if job["will_fail"]:
                job["state"] = JobState.FAILED
                job["reason"] = "injected job failure"
                return
            fut = job.get("future")
            if fut is None:
                job["future"] = self._pool.submit(self._execute, job_id)
            elif fut.done():
                err = fut.exception()
                if err is not None:
                    job["state"] = JobState.FAILED
                    job["reason"] = f"executable raised: {err!r}"
                elif job.get("exec_error"):
                    job["state"] = JobState.FAILED
                    job["reason"] = job["exec_error"]
                else:
                    job["state"] = JobState.DONE

    def _execute(self, job_id: str) -> None:
        """Run the job script (in the cluster thread pool): parse its JSON
        for the executable name and workdir, call the python executable."""
        job = self.jobs[job_id]
        if job["executed"]:
            return
        job["executed"] = True
        for fs in self.filesystems.values():
            if job["script"] in fs:
                spec = json.loads(fs[job["script"]])
                exe = self.executables.get(spec["executable"])
                workdir = spec["workdir"]
                inputs = {
                    name[len(workdir) + 1:]: data
                    for name, data in fs.items()
                    if name.startswith(workdir + "/")}
                if exe is None:
                    job["exec_error"] = f"no executable {spec['executable']}"
                    return
                outputs = exe(inputs)
                for name, data in (outputs or {}).items():
                    fs[f"{workdir}/{name}"] = data
                return
        job["exec_error"] = f"job script {job['script']} not found"

    def _squeue(self, job_ids: list[str]) -> tuple[int, str, str]:
        self.stats["queries"] += 1
        lines = []
        for jid in job_ids:
            if jid not in self.jobs:
                lines.append(f"{jid} UNDETERMINED")
                continue
            self._advance(jid)
            lines.append(f"{jid} {self.jobs[jid]['state'].value}")
        return 0, "\n".join(lines), ""


# ---------------------------------------------------------------------------
# Scheduler adapters (speak over a Transport)
# ---------------------------------------------------------------------------

class SimScheduler:
    """Talks the simulated cluster's command dialect over any transport."""

    async def submit(self, transport: Transport, script_path: str) -> str:
        rc, out, err = await transport.exec_command(f"sbatch {script_path}")
        if rc != 0:
            raise RuntimeError(f"sbatch failed ({rc}): {err}")
        return out.rsplit(" ", 1)[-1].strip()

    async def query_jobs(self, transport: Transport, job_ids: list[str]
                         ) -> dict[str, str]:
        if not job_ids:
            return {}
        rc, out, err = await transport.exec_command(
            f"squeue {','.join(job_ids)}")
        if rc != 0:
            raise RuntimeError(f"squeue failed ({rc}): {err}")
        states: dict[str, str] = {}
        for line in out.splitlines():
            jid, state = line.split()
            states[jid] = state
        return states

    async def cancel(self, transport: Transport, job_id: str) -> None:
        await transport.exec_command(f"scancel {job_id}")


class SlurmScheduler(SimScheduler):
    """Real-SLURM command generation (deployment target). Inherits the
    submit/query/cancel plumbing; adds the batch-script writer."""

    def job_script(self, *, job_name: str, command: str, nodes: int = 1,
                   tasks_per_node: int = 1, walltime: str = "01:00:00",
                   partition: str | None = None, account: str | None = None,
                   tpu_topology: str | None = None) -> str:
        lines = ["#!/bin/bash", f"#SBATCH --job-name={job_name}",
                 f"#SBATCH --nodes={nodes}",
                 f"#SBATCH --ntasks-per-node={tasks_per_node}",
                 f"#SBATCH --time={walltime}"]
        if partition:
            lines.append(f"#SBATCH --partition={partition}")
        if account:
            lines.append(f"#SBATCH --account={account}")
        if tpu_topology:
            lines.append(f"#SBATCH --gres=tpu:{tpu_topology}")
        lines += ["", "set -euo pipefail", command, ""]
        return "\n".join(lines)

    def parse_sbatch_output(self, out: str) -> str:
        # 'Submitted batch job 12345'
        return out.rsplit(" ", 1)[-1].strip()
