"""CalcJob (paper §II.B.4): the four transport tasks — upload, submit,
update, retrieve — each wrapped in exponential-back-off-retry; exhaustion
PAUSES the process instead of excepting it (fig. 3 + §II.B.4.a). The job
stage and scheduler id are checkpointed, so a restarted worker resumes a
job exactly where it was (even mid-queue on the cluster).
"""

from __future__ import annotations

import json
from typing import Any

from repro.core.datatypes import Dict, FolderData
from repro.core.exit_code import ExitCode
from repro.core.process import Process, ProcessState
from repro.core.process_spec import ProcessSpec
from repro.engine.backoff import TransportTaskExhausted, \
    exponential_backoff_retry
from repro.engine.jobmanager import JobManager
from repro.calcjobs.scheduler import JobState, SimScheduler, SimulatedCluster
from repro.provenance.store import NodeType

UPLOAD, SUBMIT, UPDATE, RETRIEVE, DONE = \
    "upload", "submit", "update", "retrieve", "done"


class CalcInfo:
    """What prepare_for_submission produces."""

    def __init__(self, *, files: dict[str, bytes], executable: str,
                 retrieve_list: list[str]):
        self.files = files
        self.executable = executable
        self.retrieve_list = retrieve_list


def get_cluster(runner) -> SimulatedCluster:
    """The runner-wide simulated cluster (swap-in point for a real one)."""
    cluster = getattr(runner, "_cluster", None)
    if cluster is None:
        cluster = SimulatedCluster()
        runner._cluster = cluster
    return cluster


def get_job_manager(runner, hostname: str) -> JobManager:
    managers = getattr(runner, "_job_managers", None)
    if managers is None:
        managers = {}
        runner._job_managers = managers
    if hostname not in managers:
        cluster = get_cluster(runner)
        if hostname not in runner.transport_queue._transports:
            runner.transport_queue.register_transport(
                cluster.make_transport(hostname))
        managers[hostname] = JobManager(runner.transport_queue,
                                        SimScheduler(), hostname)
    return managers[hostname]


class CalcJob(Process):
    NODE_TYPE = NodeType.CALC_JOB

    # backoff knobs (configurable per transport-task type, §II.B.4.a)
    MAX_ATTEMPTS = 5
    INITIAL_INTERVAL = 0.05

    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("metadata.computer", valid_type=str, required=False,
                   non_db=True, default="local")
        spec.input("metadata.options", valid_type=dict, required=False,
                   non_db=True, default=dict)
        spec.output("retrieved", valid_type=FolderData)
        spec.exit_code(100, "ERROR_SCHEDULER_FAILED",
                       "the scheduler reported the job as failed: {reason}")
        spec.exit_code(110, "ERROR_MISSING_OUTPUT",
                       "expected output file {name} was not retrieved")
        spec.exit_code(120, "ERROR_JOB_LOST",
                       "the scheduler no longer knows job {job_id}")

    # -- subclass hooks -----------------------------------------------------------
    def prepare_for_submission(self) -> CalcInfo:
        raise NotImplementedError

    def parse(self, retrieved: FolderData) -> ExitCode | None:
        """Parse retrieved files into outputs; runs locally (not a
        transport task — paper §II.B.4)."""
        return None

    # -- state for checkpointing ------------------------------------------------------
    def checkpoint_extras(self) -> dict:
        return {"stage": getattr(self, "_stage", UPLOAD),
                "job_id": getattr(self, "_job_id", None),
                "workdir": getattr(self, "_workdir", None),
                "retrieve_list": getattr(self, "_retrieve_list", [])}

    def load_checkpoint_extras(self, extras: dict) -> None:
        self._stage = extras.get("stage", UPLOAD)
        self._job_id = extras.get("job_id")
        self._workdir = extras.get("workdir")
        self._retrieve_list = extras.get("retrieve_list", [])

    # -- helpers ------------------------------------------------------------------------
    @property
    def hostname(self) -> str:
        return self.metadata.get("computer", "local")

    async def _with_backoff(self, fn, name: str):
        """Run one transport task with exponential backoff; on exhaustion
        pause the process (the paper's pause-not-except contract) and retry
        after the user (or an error handler) plays it."""
        while True:
            try:
                return await exponential_backoff_retry(
                    fn, initial_interval=self.INITIAL_INTERVAL,
                    max_attempts=self.MAX_ATTEMPTS,
                    name=f"{name}[{self.pk}]")
            except TransportTaskExhausted as exc:
                self.report("transport task %s exhausted retries: %s",
                            name, exc)
                self._pause_requested = True
                self._play.clear()
                await self._pause_point()   # blocks until play() or kill()

    # -- the lifecycle -------------------------------------------------------------------
    async def run(self):
        if not hasattr(self, "_stage"):
            self._stage = UPLOAD
            self._job_id = None
            self._workdir = None
            self._retrieve_list = []
        tq = self.runner.transport_queue
        manager = get_job_manager(self.runner, self.hostname)
        scheduler = manager.scheduler

        while self._stage != DONE:
            await self._pause_point()

            if self._stage == UPLOAD:
                info = self.prepare_for_submission()
                self._workdir = f"job_{self.pk}"
                self._retrieve_list = info.retrieve_list

                async def upload():
                    t = await tq.request_transport(self.hostname)
                    for name, data in info.files.items():
                        await t.put_file(f"{self._workdir}/{name}", data)
                    script = {"executable": info.executable,
                              "workdir": self._workdir}
                    await t.put_file(f"{self._workdir}.job",
                                     json.dumps(script).encode())

                await self._with_backoff(upload, "upload")
                self.report("uploaded %d files to %s", len(info.files),
                            self.hostname)
                self._stage = SUBMIT
                self.checkpoint_now()

            elif self._stage == SUBMIT:
                async def submit():
                    t = await tq.request_transport(self.hostname)
                    return await scheduler.submit(t, f"{self._workdir}.job")

                self._job_id = await self._with_backoff(submit, "submit")
                self.report("submitted as job %s", self._job_id)
                self._stage = UPDATE
                self.checkpoint_now()

            elif self._stage == UPDATE:
                async def update():
                    # bundled query via the job manager (paper §II.B.4.c)
                    return await self.interruptible(
                        manager.request_job_state(self._job_id))

                state = await self._with_backoff(update, "update")
                if state in (JobState.DONE.value, JobState.FAILED.value):
                    self._scheduler_state = state
                    self._stage = RETRIEVE
                    self.checkpoint_now()
                elif state == JobState.UNDETERMINED.value:
                    # Lost-job mitigation: after a node failure the scheduler
                    # may have no record of our id (e.g. this process was
                    # resumed on another worker while the original cluster
                    # allocation vanished). Resubmit from the upload stage.
                    self._undetermined = getattr(self, "_undetermined", 0) + 1
                    if self._undetermined >= 5:
                        self.report("job %s lost by scheduler; resubmitting",
                                    self._job_id)
                        self._undetermined = 0
                        self._stage = UPLOAD
                        self.checkpoint_now()
                    else:
                        import asyncio
                        await self.interruptible(asyncio.sleep(0.05))
                else:
                    import asyncio
                    self._undetermined = 0
                    self.transition_to(ProcessState.WAITING)
                    await self.interruptible(asyncio.sleep(0.02))
                    self.transition_to(ProcessState.RUNNING)

            elif self._stage == RETRIEVE:
                async def retrieve():
                    t = await tq.request_transport(self.hostname)
                    files = {}
                    for name in self._retrieve_list:
                        try:
                            files[name] = await t.get_file(
                                f"{self._workdir}/{name}")
                        except KeyError:
                            pass
                    return files

                files = await self._with_backoff(retrieve, "retrieve")
                retrieved = FolderData(files)
                self.out("retrieved", retrieved)
                self._stage = DONE

                # parsing is local — not a transport task
                sched_state = getattr(self, "_scheduler_state", None)
                if sched_state == JobState.FAILED.value:
                    job = get_cluster(self.runner).jobs.get(self._job_id, {})
                    return self.exit_codes.ERROR_SCHEDULER_FAILED.format(
                        reason=job.get("reason", "unknown"))
                missing = [n for n in self._retrieve_list if n not in files]
                if missing:
                    return self.exit_codes.ERROR_MISSING_OUTPUT.format(
                        name=missing[0])
                return self.parse(retrieved)

        return None
