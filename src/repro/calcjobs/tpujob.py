"""TPUTrainJob: the CalcJob that launches a training run on a (simulated)
TPU cluster — the pod-scale analogue of AiiDA running a DFT code via SLURM.

The job's payload is the framework's own training loop: the cluster-side
executable builds the requested architecture (reduced or full), runs
``steps`` optimizer steps and writes ``metrics.json`` + a final sharded
checkpoint manifest. ``parse`` lifts the metrics into provenance and maps
failure modes onto exit codes (NaN loss, scheduler failure, …) that error
handlers (restart.py) react to.
"""

from __future__ import annotations

import json
from typing import Any

from repro.calcjobs.calcjob import CalcInfo, CalcJob, get_cluster
from repro.core.datatypes import Dict, FolderData, Int
from repro.core.exit_code import ExitCode
from repro.core.process_spec import ProcessSpec

EXECUTABLE_NAME = "tpu_train"


def tpu_train_executable(input_files: dict[str, bytes]) -> dict[str, bytes]:
    """Cluster-side payload: a real (reduced-config) JAX training run."""
    import numpy as np

    config = json.loads(input_files["config.json"])
    import jax

    from repro.configs import get_config, reduced_config
    from repro.models.registry import build
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)
    from repro.training.optim import OptimConfig

    arch = config["arch"]
    cfg = reduced_config(arch) if config.get("reduced", True) \
        else get_config(arch)
    if config.get("overrides"):
        cfg = cfg.replace(**config["overrides"])
    bundle = build(cfg)
    tcfg = TrainConfig(optim=OptimConfig(
        lr=config.get("lr", 3e-4),
        total_steps=config.get("steps", 10),
        warmup_steps=max(1, config.get("steps", 10) // 10)))
    rng = jax.random.PRNGKey(config.get("seed", 0))
    state = init_train_state(bundle, tcfg, rng)
    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))

    b, s = config.get("batch", 2), config.get("seq", 64)
    losses = []
    data_rng = np.random.default_rng(config.get("seed", 0))
    for i in range(config.get("steps", 10)):
        tokens = data_rng.integers(0, cfg.vocab_size, (b, s + 1),
                                   dtype=np.int32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.family == "vlm":
            batch["patches"] = np.zeros((b, cfg.num_patches, cfg.d_model),
                                        np.float32)
        if cfg.family == "audio":
            batch["frames"] = data_rng.normal(
                0, 1, (b, cfg.num_frames, cfg.d_model)).astype(np.float32)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))

    if config.get("inject_nan", False):
        losses[-1] = float("nan")

    out = {
        "metrics.json": json.dumps({
            "losses": losses,
            "final_loss": losses[-1],
            "steps": len(losses),
            "arch": arch,
        }).encode(),
    }
    return out


class TPUTrainJob(CalcJob):
    @classmethod
    def define(cls, spec: ProcessSpec) -> None:
        super().define(spec)
        spec.input("config", valid_type=Dict)
        spec.output("metrics", valid_type=Dict)
        spec.exit_code(310, "ERROR_NAN_LOSS",
                       "training diverged: loss is NaN")
        spec.exit_code(311, "ERROR_NO_METRICS",
                       "metrics.json missing from retrieved files")

    def prepare_for_submission(self) -> CalcInfo:
        # make sure the cluster knows our executable
        cluster = get_cluster(self.runner)
        if EXECUTABLE_NAME not in cluster.executables:
            cluster.register_executable(EXECUTABLE_NAME, tpu_train_executable)
        cfg = dict(self.inputs["config"].value)
        return CalcInfo(
            files={"config.json": json.dumps(cfg).encode()},
            executable=EXECUTABLE_NAME,
            retrieve_list=["metrics.json"],
        )

    def parse(self, retrieved: FolderData) -> ExitCode | None:
        import math

        try:
            metrics = json.loads(retrieved.get_bytes("metrics.json"))
        except KeyError:
            return self.exit_codes.ERROR_NO_METRICS
        self.out("metrics", Dict(metrics))
        if math.isnan(metrics.get("final_loss", 0.0)):
            return self.exit_codes.ERROR_NAN_LOSS
        return None
