from repro.calcjobs.calcjob import CalcJob  # noqa: F401
from repro.calcjobs.scheduler import (  # noqa: F401
    JobState, SimScheduler, SimulatedCluster, SlurmScheduler,
)
from repro.calcjobs.tpujob import TPUTrainJob  # noqa: F401
