"""verdi-style command line interface over the provenance store.

    PYTHONPATH=src python -m repro.cli -p <profile.db> process list
    PYTHONPATH=src python -m repro.cli -p <profile.db> process report <pk>
    PYTHONPATH=src python -m repro.cli -p <profile.db> process show <pk>
    PYTHONPATH=src python -m repro.cli -p <profile.db> node show <pk>
    PYTHONPATH=src python -m repro.cli -p <profile.db> graph export <pk> --out g.dot
    PYTHONPATH=src python -m repro.cli -p <profile.db> stats
    PYTHONPATH=src python -m repro.cli -p <profile.db> cache stats
    PYTHONPATH=src python -m repro.cli -p <profile.db> cache show <pk>
    PYTHONPATH=src python -m repro.cli -p <profile.db> cache invalidate --process-type Foo
    PYTHONPATH=src python -m repro.cli -p <profile.db> cache backfill [--dry-run]

Provenance archives (cross-profile export/import, docs/archive.md):

    repro -p <profile.db> archive create -o results.zip --pk 42 [--all]
    repro -p <profile.db> archive inspect results.zip
    repro -p <other.db>   archive import results.zip

Control-plane verbs (the event-driven engine surface):

    repro -p <profile.db> process pause|play|kill|status <pk> [-w WORKDIR]
    repro -p <profile.db> process watch [--pk PK] [--once] [--timeout T]
    repro -p <profile.db> process top [--once] [--interval S]

Chaos engineering (docs/chaos.md):

    repro chaos list
    repro chaos points
    repro chaos run --scenario kill9-midstep --seed 1 [--json]
    repro -p <profile.db> chaos check [--pk PK --expect-terminal]

Observability (docs/observability.md): `stats --json` merges the node
counts with the metrics snapshots advertised by every daemon worker;
`process top` is the live worker/process table; `process report <pk>`
renders per-state dwell times and, for runs traced with REPRO_TRACE=1,
the persisted span timeline.

Mirrors the AiiDA `verdi process ...` verbs the paper's users drive the
engine with. Control verbs go through the broker's RPC channel to whichever
daemon worker owns the process; `watch` tails the state_changed.<pk>.<state>
broadcast stream live. WORKDIR is the daemon working directory holding
broker.json (default: the directory of the profile db).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.provenance.store import (
    LinkType, NodeType, ProvenanceStore, QueryBuilder,
)


def _fmt_age(ts: float) -> str:
    d = time.time() - ts
    if d < 120:
        return f"{d:.0f}s"
    if d < 7200:
        return f"{d/60:.0f}m"
    return f"{d/3600:.1f}h"


def cmd_process_list(store: ProvenanceStore, args) -> None:
    qb = (QueryBuilder(store).nodes("process").order_by("pk", desc=True)
          .project("pk", "ctime", "process_type", "process_state",
                   "exit_status", "label"))
    if args.state:
        qb = qb.with_state(args.state)
    rows = qb.limit(args.limit).all()
    print(f"{'PK':>6}  {'age':>6}  {'type':28}  {'state':10}  "
          f"{'exit':>4}  label")
    for r in rows:
        print(f"{r['pk']:>6}  {_fmt_age(r['ctime']):>6}  "
              f"{(r['process_type'] or '')[:28]:28}  "
              f"{(r['process_state'] or ''):10}  "
              f"{r['exit_status'] if r['exit_status'] is not None else '':>4}"
              f"  {r['label'] or ''}")
    total = QueryBuilder(store).nodes("process").count()
    print(f"\n{len(rows)} shown of {total} processes")


def cmd_process_report(store: ProvenanceStore, args) -> None:
    from repro.observability.timeline import (
        TRACE_LEVELNAME, load_spans, render_dwell, render_timeline,
    )

    node = store.get_node(args.pk)
    if node is None:
        sys.exit(f"no node with pk={args.pk}")
    print(f"{node['process_type']}<{args.pk}> "
          f"[{node['process_state']}] exit={node['exit_status']}")
    for log in store.get_logs(args.pk):
        if log["levelname"] == TRACE_LEVELNAME:
            continue  # span timelines get their own rendering below
        stamp = time.strftime("%H:%M:%S", time.localtime(log["time"]))
        print(f"  {stamp} [{log['levelname']}] {log['message']}")
    # recurse into called subprocesses
    for child_pk, lt, label in store.outgoing(args.pk):
        if lt.startswith("call"):
            child = store.get_node(child_pk)
            print(f"  +-- {child['process_type']}<{child_pk}> "
                  f"[{child['process_state']}] exit={child['exit_status']}")
    print("\nstate dwell times:")
    print(render_dwell(node))
    print("\nspan timeline:")
    print(render_timeline(load_spans(store, args.pk)))


def cmd_process_show(store: ProvenanceStore, args) -> None:
    node = store.get_node(args.pk)
    if node is None:
        sys.exit(f"no node with pk={args.pk}")
    print(json.dumps({k: v for k, v in node.items()
                      if k not in ("checkpoint", "payload")},
                     indent=2, default=str))
    print("inputs:")
    for pk, lt, label in store.incoming(args.pk):
        print(f"  {label:30} <- {lt:12} node {pk}")
    print("outputs:")
    for pk, lt, label in store.outgoing(args.pk):
        print(f"  {label:30} -> {lt:12} node {pk}")


def cmd_node_show(store: ProvenanceStore, args) -> None:
    node = store.get_node(args.pk)
    if node is None:
        sys.exit(f"no node with pk={args.pk}")
    if node["node_type"] == NodeType.DATA.value:
        value = store.load_data(args.pk)
        print(f"DataNode<{args.pk}> uuid={node['uuid']}")
        print(f"  value: {value!r}")
    else:
        cmd_process_show(store, args)


def cmd_graph_export(store: ProvenanceStore, args) -> None:
    """Export the provenance neighbourhood of a node as graphviz dot."""
    seen: set[int] = set()
    edges: list[tuple[int, int, str, str]] = []
    frontier = [args.pk]
    for _ in range(args.depth):
        nxt = []
        for pk in frontier:
            if pk in seen:
                continue
            seen.add(pk)
            for src, lt, label in store.incoming(pk):
                edges.append((src, pk, lt, label))
                nxt.append(src)
            for dst, lt, label in store.outgoing(pk):
                edges.append((pk, dst, lt, label))
                nxt.append(dst)
        frontier = nxt
    seen.update(pk for e in edges for pk in e[:2])

    lines = ["digraph provenance {", "  rankdir=LR;"]
    for pk in sorted(seen):
        n = store.get_node(pk)
        if n is None:
            continue
        if n["node_type"] == NodeType.DATA.value:
            shape, color = "ellipse", "lightgoldenrod"
            label = f"{pk}"
        else:
            shape = "box"
            color = {"finished": "lightgreen", "excepted": "salmon",
                     "killed": "salmon"}.get(n["process_state"], "lightblue")
            label = f"{n['process_type']}\\n({pk}) {n['process_state']}"
        lines.append(f'  n{pk} [label="{label}", shape={shape}, '
                     f'style=filled, fillcolor={color}];')
    for src, dst, lt, label in sorted(set(edges)):
        style = "dashed" if lt.startswith("call") else "solid"
        lines.append(f'  n{src} -> n{dst} [label="{label}", style={style}];')
    lines.append("}")
    out = "\n".join(lines)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(out)
        print(f"wrote {args.out} ({len(seen)} nodes, {len(set(edges))} edges)")
    else:
        print(out)


def _resolve_process_class(path: str) -> type:
    """Import a process class from 'pkg.module:Class', 'pkg.module.Class'
    or a bare name exported by repro.core / repro.calcjobs."""
    import importlib

    from repro.core.process import Process

    candidates = []
    if ":" in path:
        candidates.append(tuple(path.split(":", 1)))
    elif "." in path:
        mod, _, qual = path.rpartition(".")
        candidates.append((mod, qual))
    else:
        candidates.extend((("repro.core", path), ("repro.calcjobs", path)))
    errors = []
    for mod_name, qual in candidates:
        try:
            obj = importlib.import_module(mod_name)
            for part in qual.split("."):
                obj = getattr(obj, part)
        except (ImportError, AttributeError) as exc:
            errors.append(str(exc))
            continue
        if isinstance(obj, type) and issubclass(obj, Process):
            return obj
        # process functions carry their Process class on the wrapper
        proc_cls = getattr(obj, "process_class", None)
        if isinstance(proc_cls, type) and issubclass(proc_cls, Process):
            return proc_cls
        errors.append(f"{path} is not a Process subclass")
    sys.exit(f"cannot resolve process class {path!r}: " + "; ".join(errors))


def _print_port_tree(ns, indent: int = 2) -> None:
    from repro.core.ports import PortNamespace

    pad = " " * indent
    for name, port in sorted(ns.items()):
        if isinstance(port, PortNamespace):
            flags = [f for f, on in (("dynamic", port.dynamic),
                                     ("non_db", port.non_db)) if on]
            suffix = f" ({', '.join(flags)})" if flags else ""
            print(f"{pad}{name}/ namespace{suffix}")
            _print_port_tree(port, indent + 2)
            continue
        types = ("|".join(t.__name__ for t in port.valid_type)
                 if port.valid_type else "any")
        bits = ["required" if port.required else "optional"]
        if port.has_default:
            try:
                bits.append(f"default={port.default!r}")
            except Exception:  # noqa: BLE001 — a broken default is still info
                bits.append("default=<callable>")
        if port.serializer is not None:
            bits.append(
                f"serializer={getattr(port.serializer, '__name__', '?')}")
        if port.non_db:
            bits.append("non_db")
        help_text = f"  — {port.help}" if port.help else ""
        print(f"{pad}{name:24} {types:16} {', '.join(bits)}{help_text}")


def cmd_process_inputs(store: ProvenanceStore, args) -> None:
    """Dump a process class's declared input/output spec (the discoverable
    launch surface behind Process.get_builder())."""
    cls = _resolve_process_class(args.process_class)
    spec = cls.spec()
    print(f"{cls.__name__} ({cls.__module__}:{cls.__qualname__})")
    print("inputs:")
    _print_port_tree(spec.inputs)
    print("outputs:")
    _print_port_tree(spec.outputs)
    if len(spec.exit_codes):
        print("exit codes:")
        for label, ec in sorted(spec.exit_codes.items(),
                                key=lambda kv: kv[1].status):
            print(f"  {ec.status:>5}  {label}: {ec.message}")


def _controller(args):
    from repro.engine.controller import NoRunningDaemon, ProcessController

    workdir = args.workdir or os.path.dirname(os.path.abspath(args.profile))
    try:
        return ProcessController.from_workdir(workdir)
    except NoRunningDaemon as exc:
        sys.exit(str(exc))


def cmd_process_control(store: ProvenanceStore, args) -> None:
    """pause / play / kill / status through the broker RPC channel."""
    ctl = _controller(args)
    try:
        if args.sub == "status":
            print(json.dumps(ctl.status(args.pk), indent=2))
        elif args.sub == "kill":
            message = args.message or "killed by user"
            try:
                ctl.kill(args.pk, message)
                print(f"kill delivered to process {args.pk}")
            except (KeyError, TimeoutError):
                # not live on any worker (still queued, worker down, or
                # worker unresponsive): record the kill durably — the
                # process honours it at its next step boundary or pickup
                from repro.engine.runner import TERMINAL
                node = store.get_node(args.pk)
                if node is None:
                    sys.exit(f"no node with pk={args.pk}")
                if node.get("process_state") in TERMINAL:
                    sys.exit(f"process {args.pk} is already terminal "
                             f"({node['process_state']})")
                store.update_process(args.pk,
                                     attributes={"kill_requested": message})
                # a worker may have picked the process up (and read the
                # marker) between our first attempt and the write above —
                # retry once now that the marker is down
                try:
                    ctl.kill(args.pk, message)
                    print(f"kill delivered to process {args.pk}")
                except (KeyError, TimeoutError):
                    print(f"process {args.pk} not live; kill recorded "
                          "durably (applies at its next step boundary or "
                          "worker pickup)")
        elif args.sub == "pause":
            ctl.pause(args.pk)
            print(f"pause delivered to process {args.pk}")
        elif args.sub == "play":
            ctl.play(args.pk)
            print(f"play delivered to process {args.pk}")
    except KeyError as exc:
        sys.exit(f"process {args.pk} has no live control endpoint: {exc}")
    except TimeoutError as exc:
        sys.exit(str(exc))
    finally:
        ctl.close()


def cmd_process_watch(store: ProvenanceStore, args) -> None:
    """Tail state-change events live from the broker's event stream."""
    from repro.engine.controller import NoRunningDaemon, ProcessController

    workdir = args.workdir or os.path.dirname(os.path.abspath(args.profile))
    try:
        ctl = ProcessController.from_workdir(workdir)
    except NoRunningDaemon as exc:
        # `watch --once/--timeout` is used as a liveness probe (CI smoke):
        # a missing daemon is an answer, not an error
        if args.once or args.timeout is not None:
            print(f"{exc} — no events to watch")
            return
        sys.exit(str(exc))
    try:
        seen = 0
        for subject, sender, body in ctl.watch(
                pk=args.pk, timeout=args.timeout,
                replay_since=0 if args.replay else None):
            stamp = time.strftime("%H:%M:%S",
                                  time.localtime(body.get("ts", time.time())))
            exit_status = body.get("exit_status")
            suffix = "" if exit_status is None else f" (exit {exit_status})"
            print(f"{stamp}  pk={sender}  "
                  f"{body.get('from', '?')} -> {body.get('state', '?')}"
                  f"{suffix}", flush=True)
            seen += 1
            if args.once:
                return
        if not seen:
            print("no events within timeout")
    except KeyboardInterrupt:
        pass
    finally:
        ctl.close()


def _worker_snapshots(args) -> list[dict]:
    """Status dicts of the connected daemon workers ([] when no daemon
    is reachable — stats/top degrade to the local view then)."""
    from repro.engine.controller import NoRunningDaemon, ProcessController

    workdir = (getattr(args, "workdir", None)
               or os.path.dirname(os.path.abspath(args.profile)))
    try:
        ctl = ProcessController.from_workdir(workdir, timeout=5.0)
    except NoRunningDaemon:
        return []
    try:
        return ctl.workers()
    except (ConnectionError, TimeoutError):
        return []
    finally:
        ctl.close()


def cmd_stats(store: ProvenanceStore, args) -> None:
    from repro.observability.metrics import get_registry, merge_snapshots

    workers = _worker_snapshots(args)
    # this CLI process's own instruments (store stats from the profile
    # open above) merged with every worker's advertised snapshot
    merged = merge_snapshots(
        [get_registry().snapshot()]
        + [w.get("metrics") or {} for w in workers])
    node_counts = {}
    for nt in NodeType:
        c = QueryBuilder(store).nodes(nt).count() if nt != NodeType.DATA \
            else store.count_nodes(NodeType.DATA)
        if c:
            node_counts[nt.value] = c
    unfinished = store.unfinished_processes()

    if getattr(args, "json", False):
        print(json.dumps({
            "nodes": node_counts,
            "unfinished": len(unfinished),
            "metrics": merged,
            "repository": store.repository.stats(),
            "workers": [{k: v for k, v in w.items() if k != "metrics"}
                        for w in workers],
        }, indent=2))
        return

    print("node counts:")
    for name, c in node_counts.items():
        print(f"  {name:24} {c}")
    unfin = unfinished
    print(f"unfinished processes: {len(unfin)}")
    for n in unfin[:10]:
        print(f"  pk={n['pk']} {n['process_type']} [{n['process_state']}]")
    repo = store.repository.stats()
    print(f"repository: {repo['blobs']} blob(s), {repo['bytes']} byte(s)")
    if workers:
        print(f"daemon workers: {len(workers)}")
        for w in workers:
            print(f"  {w.get('worker', '?'):28} slots={w.get('slots', '?')}"
                  f" running={len(w.get('pks') or [])}")
    if merged["counters"]:
        print("counters:")
        for name, v in merged["counters"].items():
            print(f"  {name:32} {v}")
    for name, h in merged["histograms"].items():
        if h.get("count"):
            mean = h["sum"] / h["count"]
            print(f"  {name:32} n={h['count']} mean={mean * 1e3:.2f}ms")


def cmd_process_top(store: ProvenanceStore, args) -> None:
    """Live table of workers + the processes they are driving — the
    `verdi process list --live` answer, fed by worker advertisements."""
    from repro.engine.controller import NoRunningDaemon, ProcessController
    from repro.provenance.store import SUMMARY_COLUMNS

    workdir = (getattr(args, "workdir", None)
               or os.path.dirname(os.path.abspath(args.profile)))

    def render_once(ctl) -> None:
        workers = ctl.workers()
        print(time.strftime("%H:%M:%S"), f"— {len(workers)} worker(s)")
        print(f"{'worker':28}  {'pid':>7}  {'slots':>5}  {'run':>4}  "
              f"{'tasks':>6}  {'commits':>8}  {'rpc mean':>9}")
        for w in workers:
            snap = w.get("metrics") or {}
            counters = snap.get("counters") or {}
            rpc = (snap.get("histograms") or {}).get("broker.rpc_seconds")
            rpc_mean = (f"{rpc['sum'] / rpc['count'] * 1e3:.1f}ms"
                        if rpc and rpc.get("count") else "-")
            print(f"{w.get('worker', '?'):28}  {w.get('pid', ''):>7}  "
                  f"{w.get('slots', ''):>5}  {len(w.get('pks') or []):>4}  "
                  f"{counters.get('daemon.tasks', 0):>6}  "
                  f"{counters.get('store.commits', 0):>8}  {rpc_mean:>9}")
        pks = sorted({pk for w in workers for pk in (w.get("pks") or [])})
        if pks:
            rows = store.get_nodes(pks, columns=SUMMARY_COLUMNS)
            print(f"\n{'PK':>6}  {'age':>6}  {'type':28}  state")
            for pk in pks:
                node = rows.get(pk)
                if node is None:
                    continue
                print(f"{node['pk']:>6}  {_fmt_age(node['ctime']):>6}  "
                      f"{(node['process_type'] or '')[:28]:28}  "
                      f"{node['process_state'] or ''}")
        else:
            print("\nno live processes")

    try:
        ctl = ProcessController.from_workdir(workdir, timeout=5.0)
    except NoRunningDaemon as exc:
        # like `watch --once`: a missing daemon is an answer, not an error
        if args.once:
            print(f"{exc} — nothing running")
            return
        sys.exit(str(exc))
    try:
        while True:
            render_once(ctl)
            if args.once:
                return
            time.sleep(args.interval)
            print()
    except KeyboardInterrupt:
        pass
    finally:
        ctl.close()


def cmd_cache_stats(store: ProvenanceStore, args) -> None:
    from repro.caching.registry import CacheRegistry

    stats = CacheRegistry(store).stats()
    print(f"{'process type':28}  {'hashed':>7}  {'distinct':>8}  "
          f"{'cache hits':>10}  {'collisions':>10}")
    for ptype, row in stats["process_types"].items():
        print(f"{ptype[:28]:28}  {row['hashed_nodes']:>7}  "
              f"{row['distinct_hashes']:>8}  {row['cache_hits']:>10}  "
              f"{row['hash_collisions']:>10}")
    print(f"\n{stats['hashed_nodes']} hashed process nodes, "
          f"{stats['cache_hits']} cache hits, "
          f"{stats['hash_collisions']} hash-collision occurrence(s)")
    if stats["hash_collisions"]:
        print("WARNING: same-fingerprint nodes produced different outputs;"
              " check CACHE_VERSION / exclude_from_hash declarations")


def cmd_cache_show(store: ProvenanceStore, args) -> None:
    from repro.caching.registry import CacheRegistry

    node = store.get_node(args.pk)
    if node is None:
        sys.exit(f"no node with pk={args.pk}")
    if not node["node_type"].startswith("process"):
        sys.exit(f"node {args.pk} is a {node['node_type']} node; only "
                 "process nodes carry cache fingerprints")
    attrs = json.loads(node.get("attributes") or "{}")
    print(f"{node['process_type']}<{args.pk}> "
          f"[{node['process_state']}] exit={node['exit_status']}")
    print(f"  node_hash:   {node.get('node_hash') or '(invalidated/none)'}")
    if "cached_from" in attrs:
        print(f"  cached_from: {attrs['cached_from']} "
              f"(pk={attrs.get('cached_from_pk')})")
    else:
        print("  cached_from: — (computed, not cloned)")
    eq = CacheRegistry(store).equivalents(args.pk)
    print(f"  equivalents: {eq if eq else 'none'}")


def cmd_cache_backfill(store: ProvenanceStore, args) -> None:
    """Re-hash legacy (pre-caching) nodes so they serve cache hits."""
    from repro.caching.backfill import backfill_hashes

    stats = backfill_hashes(
        store,
        resolve_modules=args.resolve,
        process_types=args.process_type or None,
        batch_size=args.batch_size,
        dry_run=args.dry_run,
        include_invalidated=args.include_invalidated,
        progress=print)
    verb = "would hash" if stats.dry_run else "hashed"
    print(f"{verb} {stats.hashed} of {stats.scanned} legacy node(s)")
    for ptype, n in sorted(stats.by_type.items()):
        print(f"  {ptype:28} {n}")
    if stats.skipped_unresolvable:
        print(f"  {stats.skipped_unresolvable} skipped: process class not "
              "importable (pass --resolve <module> for classes defined "
              "outside repro.core/repro.calcjobs)")
    if stats.skipped_invalidated:
        print(f"  {stats.skipped_invalidated} skipped: fingerprint was "
              "deliberately invalidated (--include-invalidated to re-hash)")
    if stats.skipped_error:
        print(f"  {stats.skipped_error} skipped: input reconstruction or "
              "hashing failed")
    if stats.collisions:
        print(f"WARNING: {stats.collisions} backfilled node(s) join an "
              "equivalence class with differing outputs (hash collision)")


def cmd_archive_create(store: ProvenanceStore, args) -> None:
    from repro.provenance.archive import export_archive

    pks = args.pk or None
    if not args.all and not pks:
        sys.exit("give node selections with --pk (repeatable), or --all")
    manifest = export_archive(
        store, args.output, pks,
        ancestors=not args.no_ancestors,
        descendants=not args.no_descendants,
        source=os.path.abspath(args.profile))
    print(f"wrote {args.output}: {manifest['nodes']} node(s), "
          f"{manifest['links']} link(s), {manifest['logs']} log(s), "
          f"{manifest['payload_files']} array payload(s)")
    print(f"content digest {manifest['content_digest']}")


def cmd_archive_inspect(store: ProvenanceStore, args) -> None:
    from repro.provenance.archive import ArchiveError, read_manifest

    try:
        manifest = read_manifest(args.archive)
    except ArchiveError as exc:
        sys.exit(str(exc))
    print(f"{args.archive} (archive version "
          f"{manifest['archive_version']})")
    if manifest.get("source"):
        print(f"  source:  {manifest['source']}")
    print(f"  nodes:   {manifest['nodes']}")
    for ntype, n in manifest.get("node_types", {}).items():
        print(f"    {ntype:24} {n}")
    print(f"  links:   {manifest['links']}")
    print(f"  logs:    {manifest['logs']}")
    print(f"  arrays:  {manifest['payload_files']}")
    print(f"  digest:  {manifest['content_digest']}")


def cmd_archive_import(store: ProvenanceStore, args) -> None:
    from repro.provenance.archive import ArchiveError, import_archive

    try:
        result = import_archive(store, args.archive,
                                dedup=not args.no_dedup, progress=print)
    except ArchiveError as exc:
        sys.exit(str(exc))
    if result.nodes_imported == 0:
        print("nothing new to import (all archive nodes already present "
              "or content-equivalent)")


# ---------------------------------------------------------------------------
# chaos (docs/chaos.md)
# ---------------------------------------------------------------------------

def cmd_chaos_run(store: ProvenanceStore, args) -> None:
    from repro.chaos.harness import SCENARIOS, run_scenario

    if args.scenario not in SCENARIOS:
        sys.exit(f"unknown scenario {args.scenario!r}; "
                 f"try: {', '.join(sorted(SCENARIOS))}")
    result = run_scenario(args.scenario, seed=args.seed,
                          workdir=args.workdir)
    if args.json:
        print(json.dumps({
            "scenario": result.name, "seed": result.seed, "ok": result.ok,
            "restarts": result.restarts, "elapsed": result.elapsed,
            "states": {str(k): v for k, v in result.states.items()},
            "violations": [str(v) for v in result.report.violations],
            "failures": result.failures,
            "broker_stats": result.broker_stats,
            "workdir": result.workdir}, indent=2))
    else:
        print(result.summary())
    if not result.ok:
        sys.exit(1)


def cmd_chaos_list(store: ProvenanceStore, args) -> None:
    from repro.chaos.harness import list_scenarios

    for sc in list_scenarios():
        print(f"{sc.name:<20} {sc.description}")
        if sc.chaos:
            print(f"{'':<20} faults: {sc.chaos}")


def cmd_chaos_points(store: ProvenanceStore, args) -> None:
    from repro.chaos.faults import CATALOG

    for name, desc in sorted(CATALOG.items()):
        print(f"{name:<24} {desc}")


def cmd_chaos_check(store: ProvenanceStore, args) -> None:
    from repro.chaos.invariants import check_store

    report = check_store(store, expected_pks=args.pk or None,
                         expect_terminal=args.expect_terminal)
    print(report.summary())
    if not report.ok:
        sys.exit(1)


def cmd_store_fsck(store: ProvenanceStore, args) -> None:
    from repro.provenance.fsck import fsck

    broker_db = args.broker_db
    if broker_db is None:
        # daemon convention: broker.db sits next to the profile
        candidate = os.path.join(
            os.path.dirname(os.path.abspath(args.profile)), "broker.db")
        if os.path.exists(candidate):
            broker_db = candidate
    report = fsck(store, repair=args.repair, broker_db=broker_db)
    if args.json:
        print(json.dumps({
            "clean": report.clean,
            "repaired": report.repaired,
            "counts": report.counts(),
            "checked": {"processes": report.checked_processes,
                        "links": report.checked_links,
                        "blobs": report.checked_blobs},
            "findings": [{"kind": f.kind, "pk": f.pk, "detail": f.detail,
                          "action": f.action} for f in report.findings],
        }, indent=2))
    else:
        print(report.summary())
    # detect-only mode exits non-zero on findings (CI gate); --repair
    # exits zero when every finding was fixed
    if report.findings and not args.repair:
        sys.exit(1)


def cmd_cache_invalidate(store: ProvenanceStore, args) -> None:
    from repro.caching.registry import CacheRegistry

    given = [args.all, args.pk is not None, bool(args.process_type)]
    if sum(given) != 1:
        sys.exit("give exactly one of --pk, --process-type or --all")
    n = CacheRegistry(store).invalidate(
        pk=args.pk, process_type=args.process_type or None)
    print(f"invalidated {n} node(s)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="repro.cli")
    ap.add_argument("-p", "--profile", default="examples_out/train_lm.db",
                    help="provenance sqlite file")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_proc = sub.add_parser("process")
    proc_sub = p_proc.add_subparsers(dest="sub", required=True)
    pl = proc_sub.add_parser("list")
    pl.add_argument("--state", default=None)
    pl.add_argument("--limit", type=int, default=30)
    pr = proc_sub.add_parser("report")
    pr.add_argument("pk", type=int)
    ps = proc_sub.add_parser("show")
    ps.add_argument("pk", type=int)
    pi = proc_sub.add_parser(
        "inputs", help="dump a process class's input/output spec")
    pi.add_argument("process_class",
                    help="e.g. repro.calcjobs:TPUTrainJob or TPUTrainJob")
    for verb in ("pause", "play", "kill", "status"):
        pc = proc_sub.add_parser(verb)
        pc.add_argument("pk", type=int)
        pc.add_argument("-w", "--workdir", default=None,
                        help="daemon workdir holding broker.json "
                             "(default: profile directory)")
        if verb == "kill":
            pc.add_argument("--message", default="")
    pw = proc_sub.add_parser("watch")
    pw.add_argument("--pk", type=int, default=None)
    pw.add_argument("--once", action="store_true",
                    help="exit after the first event")
    pw.add_argument("--timeout", type=float, default=None,
                    help="stop watching after this many seconds")
    pw.add_argument("--replay", action="store_true",
                    help="first replay events the broker has logged")
    pw.add_argument("-w", "--workdir", default=None,
                    help="daemon workdir holding broker.json "
                         "(default: profile directory)")
    pt = proc_sub.add_parser(
        "top", help="live table of workers + the processes they drive")
    pt.add_argument("--once", action="store_true",
                    help="render one frame and exit")
    pt.add_argument("--interval", type=float, default=2.0,
                    help="seconds between refreshes (default 2)")
    pt.add_argument("-w", "--workdir", default=None,
                    help="daemon workdir holding broker.json "
                         "(default: profile directory)")

    p_node = sub.add_parser("node")
    node_sub = p_node.add_subparsers(dest="sub", required=True)
    ns = node_sub.add_parser("show")
    ns.add_argument("pk", type=int)

    p_graph = sub.add_parser("graph")
    graph_sub = p_graph.add_subparsers(dest="sub", required=True)
    ge = graph_sub.add_parser("export")
    ge.add_argument("pk", type=int)
    ge.add_argument("--out", default="")
    ge.add_argument("--depth", type=int, default=3)

    p_stats = sub.add_parser("stats")
    p_stats.add_argument("--json", action="store_true",
                         help="machine-readable merged stats document")
    p_stats.add_argument("-w", "--workdir", default=None,
                         help="daemon workdir holding broker.json "
                              "(default: profile directory)")

    p_cache = sub.add_parser("cache")
    cache_sub = p_cache.add_subparsers(dest="sub", required=True)
    cache_sub.add_parser("stats")
    cs = cache_sub.add_parser("show")
    cs.add_argument("pk", type=int)
    ci = cache_sub.add_parser("invalidate")
    ci.add_argument("--pk", type=int, default=None)
    ci.add_argument("--process-type", default="")
    ci.add_argument("--all", action="store_true")
    cb = cache_sub.add_parser(
        "backfill", help="re-hash legacy (pre-caching) process nodes")
    cb.add_argument("--dry-run", action="store_true",
                    help="report what would be hashed without writing")
    cb.add_argument("--batch-size", type=int, default=200)
    cb.add_argument("--process-type", action="append", default=[],
                    help="only backfill these process types (repeatable)")
    cb.add_argument("--resolve", action="append", default=[],
                    metavar="MODULE",
                    help="extra module(s) to import process classes from")
    cb.add_argument("--include-invalidated", action="store_true",
                    help="also re-hash deliberately invalidated nodes")

    p_arch = sub.add_parser(
        "archive", help="export/import provenance between profiles")
    arch_sub = p_arch.add_subparsers(dest="sub", required=True)
    ac = arch_sub.add_parser("create")
    ac.add_argument("-o", "--output", required=True,
                    help="archive file to write (zip)")
    ac.add_argument("--pk", type=int, action="append", default=[],
                    help="seed node(s); the export is their graph closure")
    ac.add_argument("--all", action="store_true",
                    help="export the whole profile")
    ac.add_argument("--no-ancestors", action="store_true",
                    help="do not traverse to provenance ancestors")
    ac.add_argument("--no-descendants", action="store_true",
                    help="do not traverse to created data / sub-calls")
    ai = arch_sub.add_parser("inspect")
    ai.add_argument("archive")
    am = arch_sub.add_parser("import")
    am.add_argument("archive")
    am.add_argument("--no-dedup", action="store_true",
                    help="import content-equivalent finished-ok nodes "
                         "instead of mapping them onto existing ones")

    p_chaos = sub.add_parser(
        "chaos", help="fault injection scenarios + invariant checking")
    chaos_sub = p_chaos.add_subparsers(dest="sub", required=True)
    cr = chaos_sub.add_parser(
        "run", help="run one scenario against a throwaway daemon")
    cr.add_argument("--scenario", required=True)
    cr.add_argument("--seed", type=int, default=1)
    cr.add_argument("--workdir", default=None,
                    help="daemon workdir (default: fresh temp dir)")
    cr.add_argument("--json", action="store_true")
    chaos_sub.add_parser("list", help="list scenarios")
    chaos_sub.add_parser("points", help="list registered fault points")
    cc = chaos_sub.add_parser(
        "check", help="run the provenance invariant checker on the profile")
    cc.add_argument("--pk", type=int, action="append", default=[],
                    help="pk(s) that must exist (repeatable)")
    cc.add_argument("--expect-terminal", action="store_true",
                    help="also require --pk processes to be terminal")

    p_store = sub.add_parser(
        "store", help="profile maintenance (fsck, repair, blob GC)")
    store_sub = p_store.add_subparsers(dest="sub", required=True)
    sf = store_sub.add_parser(
        "fsck", help="detect (and with --repair, fix) orphaned processes, "
                     "stale checkpoints, dangling links, unreferenced blobs")
    sf.add_argument("--repair", action="store_true",
                    help="fix findings in place instead of just reporting")
    sf.add_argument("--broker-db", default=None,
                    help="broker sqlite for live-lease detection + requeue "
                         "(default: broker.db next to the profile)")
    sf.add_argument("--json", action="store_true")

    args = ap.parse_args(argv)
    store = ProvenanceStore(args.profile)

    if args.cmd == "process" and args.sub == "list":
        cmd_process_list(store, args)
    elif args.cmd == "process" and args.sub == "report":
        cmd_process_report(store, args)
    elif args.cmd == "process" and args.sub == "show":
        cmd_process_show(store, args)
    elif args.cmd == "process" and args.sub == "inputs":
        cmd_process_inputs(store, args)
    elif args.cmd == "process" and args.sub in ("pause", "play", "kill",
                                                "status"):
        cmd_process_control(store, args)
    elif args.cmd == "process" and args.sub == "watch":
        cmd_process_watch(store, args)
    elif args.cmd == "process" and args.sub == "top":
        cmd_process_top(store, args)
    elif args.cmd == "node" and args.sub == "show":
        cmd_node_show(store, args)
    elif args.cmd == "graph" and args.sub == "export":
        cmd_graph_export(store, args)
    elif args.cmd == "stats":
        cmd_stats(store, args)
    elif args.cmd == "cache" and args.sub == "stats":
        cmd_cache_stats(store, args)
    elif args.cmd == "cache" and args.sub == "show":
        cmd_cache_show(store, args)
    elif args.cmd == "cache" and args.sub == "invalidate":
        cmd_cache_invalidate(store, args)
    elif args.cmd == "cache" and args.sub == "backfill":
        cmd_cache_backfill(store, args)
    elif args.cmd == "archive" and args.sub == "create":
        cmd_archive_create(store, args)
    elif args.cmd == "archive" and args.sub == "inspect":
        cmd_archive_inspect(store, args)
    elif args.cmd == "archive" and args.sub == "import":
        cmd_archive_import(store, args)
    elif args.cmd == "chaos" and args.sub == "run":
        cmd_chaos_run(store, args)
    elif args.cmd == "chaos" and args.sub == "list":
        cmd_chaos_list(store, args)
    elif args.cmd == "chaos" and args.sub == "points":
        cmd_chaos_points(store, args)
    elif args.cmd == "chaos" and args.sub == "check":
        cmd_chaos_check(store, args)
    elif args.cmd == "store" and args.sub == "fsck":
        cmd_store_fsck(store, args)


if __name__ == "__main__":
    main()
