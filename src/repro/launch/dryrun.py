import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# This file is the ONLY place the 512 placeholder devices are forced; smoke
# tests and benchmarks see the real (single) CPU device.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, ARCH_IDS               # noqa: E402
from repro.distributed.sharding import (                     # noqa: E402
    make_rules, tree_named_shardings)
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models.common import axis_rules                   # noqa: E402
from repro.models.registry import SHAPES, build              # noqa: E402
from repro.serving.serve import make_decode_step, make_prefill_step  # noqa: E402
from repro.training.train_step import (                      # noqa: E402
    TrainConfig, make_train_step, train_state_axes, train_state_shapes)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s+(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, pod_stride: int = 256) -> dict:
    """Per-device wire-byte estimates per collective kind (ring model).

    all-gather: S*(n-1)/n   all-reduce: 2*S*(n-1)/n
    reduce-scatter: S_out*(n-1)   all-to-all: S*(n-1)/n   permute: S
    where S is the op's output bytes and n the replica-group size.
    """
    out = {k: 0.0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    dcn_bytes = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        s = _shape_bytes(m.group("shape"))
        n = 1
        cross_pod = False
        ge = _GROUPS_EXPL_RE.search(line)
        if ge:
            ids = [int(x) for x in ge.group(1).split(",")]
            n = len(ids)
            cross_pod = len({i // pod_stride for i in ids}) > 1
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        if n <= 1:
            continue
        if op == "all-gather":
            wire = s * (n - 1) / n
        elif op == "all-reduce":
            wire = 2.0 * s * (n - 1) / n
        elif op == "reduce-scatter":
            wire = s * (n - 1)
        elif op == "all-to-all":
            wire = s * (n - 1) / n
        else:
            wire = float(s)
        out[op] += wire
        counts[op] += 1
        if cross_pod:
            dcn_bytes += wire
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": sum(out.values()),
            "dcn_wire_bytes": dcn_bytes}


@dataclasses.dataclass(frozen=True)
class Variant:
    """Sharding/numerics knobs explored by the §Perf hillclimb."""

    name: str = "baseline"
    fsdp: bool = False               # paper-naive baseline: pure DP + TP
    fsdp_over_pod: bool = False
    act_seq_shard: bool = False
    microbatches: int = 1
    remat_policy: str = "nothing_saveable"
    kv_cache_dtype: str = "bfloat16"
    attn_impl: str = ""              # '' = config default
    param_dtype: str = "float32"
    optimizer: str = "adamw"
    parallelism: str = "tp"          # tp | zero3 | serve2d
    ce_chunk: int = 0                # chunked cross-entropy (0 = off)
    moe_capacity_factor: float = 0.0  # 0 = config default


BASELINE = Variant()
OPTIMIZED = Variant(name="optimized", fsdp=True, act_seq_shard=False,
                    remat_policy="dots_with_no_batch_dims_saveable")

VARIANTS = {
    "baseline": BASELINE,
    "optimized": OPTIMIZED,
    # §Perf hillclimb variants ------------------------------------------------
    # ZeRO-3: both in-pod axes are data parallel; params fully sharded and
    # all-gathered per layer. Kills the per-layer TP activation all-reduces.
    "zero3": Variant(name="zero3", parallelism="zero3",
                     remat_policy="dots_with_no_batch_dims_saveable"),
    # + Adafactor (factored second moment) for the 314B-class footprint
    "zero3_af": Variant(name="zero3_af", parallelism="zero3",
                        remat_policy="dots_with_no_batch_dims_saveable",
                        optimizer="adafactor"),
    # ZeRO-3 with full remat (trades compute for activation memory)
    "zero3_full_remat": Variant(name="zero3_full_remat", parallelism="zero3",
                                remat_policy="nothing_saveable"),
    # + chunked cross-entropy: never materialize (B, S, vocab) fp32 logits
    "zero3_ce": Variant(name="zero3_ce", parallelism="zero3",
                        remat_policy="nothing_saveable", ce_chunk=512),
    # ZeRO-3 with bf16 parameter storage: all-gathers move half the bytes
    "zero3_bf16": Variant(name="zero3_bf16", parallelism="zero3",
                          remat_policy="dots_with_no_batch_dims_saveable",
                          param_dtype="bfloat16"),
    # ZeRO-3 + 4-way microbatch accumulation (activation memory / collective
    # frequency trade)
    "zero3_mb4": Variant(name="zero3_mb4", parallelism="zero3",
                         remat_policy="dots_with_no_batch_dims_saveable",
                         microbatches=4),
    # MoE: capacity factor 1.0 — shrinks the structural capacity-tensor
    # all-reduce of TP-in-expert (E*C/g: 2.5x -> 2.0x token count)
    "tp_cf1": Variant(name="tp_cf1", moe_capacity_factor=1.0,
                      remat_policy="dots_with_no_batch_dims_saveable"),
    # serving: bf16 weights + int8 KV cache, TP sharding
    "serve_opt": Variant(name="serve_opt", param_dtype="bfloat16",
                         kv_cache_dtype="int8"),
    # serving: additionally 2D-shard the weights (embed dim over 'data')
    "serve_opt_2d": Variant(name="serve_opt_2d", param_dtype="bfloat16",
                            kv_cache_dtype="int8", fsdp=True),
    # serving: 2D-stationary weights + replicated (tiny) decode activations:
    # GSPMD re-shards tokens between attention and matmuls instead of
    # all-gathering weight shards each step
    "serve_act": Variant(name="serve_act", param_dtype="bfloat16",
                         kv_cache_dtype="int8", parallelism="serve2d"),
}


def _apply_variant(cfg, var: Variant):
    kw = dict(remat_policy=var.remat_policy, kv_cache_dtype=var.kv_cache_dtype,
              param_dtype=var.param_dtype, use_pallas=False,
              ce_chunk=var.ce_chunk)
    if var.attn_impl:
        kw["attn_impl"] = var.attn_impl
    if var.moe_capacity_factor:
        kw["moe_capacity_factor"] = var.moe_capacity_factor
    return cfg.replace(**kw)


def total_param_count(bundle) -> int:
    import math

    shapes = jax.tree.leaves(bundle.param_shapes())
    return sum(math.prod(s.shape) for s in shapes)


def active_param_count(bundle) -> int:
    """MoE: experts contribute k/E of their parameters per token."""
    cfg = bundle.cfg
    if cfg.family != "moe":
        return total_param_count(bundle)
    total = 0
    # jax.tree.flatten_with_path only exists from jax 0.4.38; fall back to
    # the tree_util spelling on older versions
    flatten_with_path = getattr(jax.tree, "flatten_with_path",
                                jax.tree_util.tree_flatten_with_path)
    flat = flatten_with_path(bundle.param_shapes())[0]
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= int(d)
        keys = "/".join(str(p) for p in path)
        if "moe" in keys and ("w_gate" in keys or "w_up" in keys or
                              "w_down" in keys):
            n = n * cfg.num_experts_per_tok // cfg.num_experts
        total += n
    return total


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               var: Variant = BASELINE, layers: int | None = None):
    """Lower + compile one (arch x shape x mesh) cell. Returns stats dict.

    ``layers`` overrides the depth and unrolls the stack — used by the
    collective-bytes slope extraction (L=2 vs L=4, extrapolated to full L,
    because XLA cost analysis counts scan bodies once)."""
    cfg = _apply_variant(get_config(arch), var)
    if layers is not None:
        kw = {"num_layers": layers, "unroll_layers": True}
        if cfg.encoder_layers:
            kw["encoder_layers"] = layers
        cfg = cfg.replace(**kw)
    bundle = build(cfg)
    cell = SHAPES[shape_name]
    ok, reason = bundle.supports_cell(cell)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "variant": var.name, "skipped": True, "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh, fsdp=var.fsdp,
                       fsdp_over_pod=var.fsdp_over_pod,
                       act_seq_shard=var.act_seq_shard,
                       parallelism=var.parallelism)
    notes: list[str] = []
    t0 = time.time()

    from repro.training.optim import OptimConfig

    with axis_rules(mesh, rules):
        if cell.kind == "train":
            tcfg = TrainConfig(microbatches=var.microbatches,
                               optim=OptimConfig(name=var.optimizer))
            state_struct = train_state_shapes(bundle, tcfg)
            state_axes = train_state_axes(bundle, tcfg)
            state_sh = tree_named_shardings(state_struct, state_axes, rules,
                                            mesh, notes)
            batch_struct = bundle.batch_struct(cell)
            batch_sh = tree_named_shardings(batch_struct,
                                            bundle.batch_axes(cell),
                                            rules, mesh, notes)
            step = make_train_step(bundle, tcfg)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_struct, batch_struct)
        else:
            pshapes = bundle.param_shapes()
            params_sh = tree_named_shardings(pshapes, bundle.param_axes(),
                                             rules, mesh, notes)
            b = cell.global_batch
            max_len = cell.seq_len
            cache_struct = jax.eval_shape(
                lambda: bundle.init_cache(b, max_len))
            cache_sh = tree_named_shardings(cache_struct, bundle.cache_axes(),
                                            rules, mesh, notes)
            if cell.kind == "prefill":
                batch_struct = bundle.batch_struct(cell)
                batch_sh = tree_named_shardings(batch_struct,
                                                bundle.batch_axes(cell),
                                                rules, mesh, notes)
                step = make_prefill_step(bundle)
                jitted = jax.jit(step, in_shardings=(params_sh, batch_sh,
                                                     cache_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(pshapes, batch_struct, cache_struct)
            else:  # decode
                tok_struct = jax.ShapeDtypeStruct((b, 1), jnp.int32)
                tok_sh = tree_named_shardings(
                    tok_struct, ("batch", None), rules, mesh, notes)
                pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
                step = make_decode_step(bundle)
                jitted = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                     tok_sh,
                                                     NamedSharding(mesh, P())),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(pshapes, cache_struct, tok_struct,
                                       pos_struct)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as exc:  # noqa: BLE001
        cost = {"error": str(exc)}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as exc:  # noqa: BLE001
        mem = {"error": str(exc)}

    coll = collective_stats(compiled.as_text())

    n_params = total_param_count(bundle)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": var.name if layers is None else f"{var.name}_L{layers}",
        "layers_override": layers,
        "variant_detail": dataclasses.asdict(var),
        "skipped": False,
        "n_devices": mesh.devices.size,
        "params_total": n_params,
        "params_active": active_param_count(bundle),
        "tokens_per_step": (cell.global_batch * cell.seq_len
                            if cell.kind != "decode" else cell.global_batch),
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": cost,
        "memory_analysis": mem,
        "collectives": coll,
        "sharding_notes": notes[:40],
    }
    return result


def cell_filename(arch, shape, mesh, variant):
    return f"{arch}__{shape}__{mesh}__{variant}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--slope", action="store_true",
                    help="also lower unrolled L=2/L=4 cells for the "
                         "collective-bytes extrapolation")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()}; "
        "run as its own process")

    archs = [a for a in ARCH_IDS if a != "aiida-demo-110m"] \
        if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    var = VARIANTS[args.variant]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    # Scanned-stack families need the L2/L4 unrolled slope cells; the
    # hybrid/ssm families are already unrolled (collectives exact).
    def slope_layer_counts(arch: str) -> list[int]:
        fam = get_config(arch).family
        return [2, 4] if fam in ("dense", "moe", "vlm", "audio") else []

    jobs: list[tuple[str, str, str, int | None]] = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                jobs.append((arch, shape, mesh_name, None))
                if args.slope:
                    for lc in slope_layer_counts(arch):
                        jobs.append((arch, shape, mesh_name, lc))

    for arch, shape, mesh_name, layers in jobs:
        vname = var.name if layers is None else f"{var.name}_L{layers}"
        fname = outdir / cell_filename(arch, shape, mesh_name, vname)
        if fname.exists() and not args.force:
            print(f"[skip] {fname.name} (cached)")
            continue
        print(f"[cell] {arch} x {shape} x {mesh_name} ({vname}) ...",
              flush=True)
        try:
            res = lower_cell(arch, shape, multi_pod=(mesh_name == "multi"),
                             var=var, layers=layers)
        except Exception:  # noqa: BLE001
            res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "variant": vname, "skipped": False,
                   "error": traceback.format_exc()[-4000:]}
        fname.write_text(json.dumps(res, indent=1))
        status = ("SKIP" if res.get("skipped")
                  else "ERR" if "error" in res else
                  f"ok lower={res.get('lower_s')}s "
                  f"compile={res.get('compile_s')}s")
        print(f"[done] {fname.name}: {status}", flush=True)


if __name__ == "__main__":
    main()
