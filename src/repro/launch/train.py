"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --batch 2 --seq 64 --reduced --ckpt-dir /tmp/run1

Wires together: config -> mesh + sharding rules -> data pipeline -> jitted
train step -> sharded/elastic checkpoints, with resume-from-latest. On a
real installation this is the entry point each TPU worker runs (the engine
submits it via TPUTrainJob/SLURM); on CPU it trains reduced configs.
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.distributed.sharding import make_rules, tree_named_shardings
from repro.launch.mesh import make_local_mesh
from repro.models.common import axis_rules
from repro.models.registry import build
from repro.training import checkpoint as ckpt_mod
from repro.training.data import DataConfig, TokenStream
from repro.training.optim import OptimConfig
from repro.training.train_step import (
    TrainConfig, init_train_state, make_train_step, train_state_axes,
    train_state_shapes,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build(cfg)
    mesh = make_local_mesh(args.data_mesh, args.model_mesh)
    rules = make_rules(cfg, mesh, fsdp=args.data_mesh > 1)
    tcfg = TrainConfig(
        optim=OptimConfig(name=args.optimizer, lr=args.lr,
                          warmup_steps=max(1, args.steps // 20),
                          total_steps=args.steps),
        microbatches=args.microbatches,
        seed=args.seed)

    data = TokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=args.seed,
        host_id=jax.process_index(), num_hosts=jax.process_count()))

    with axis_rules(mesh, rules):
        state_sh = tree_named_shardings(
            train_state_shapes(bundle, tcfg), train_state_axes(bundle, tcfg),
            rules, mesh)
        step_fn = jax.jit(make_train_step(bundle, tcfg),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))

        start_step = 0
        if args.ckpt_dir and ckpt_mod.latest_step(args.ckpt_dir) is not None:
            target = jax.eval_shape(
                lambda: init_train_state(bundle, tcfg, jax.random.PRNGKey(0)))
            state = ckpt_mod.restore_checkpoint(args.ckpt_dir, target=target,
                                                shardings=state_sh)
            start_step = int(state["step"])
            print(f"[train] resumed from step {start_step}")
        else:
            state = init_train_state(bundle, tcfg,
                                     jax.random.PRNGKey(args.seed))
        checkpointer = (ckpt_mod.AsyncCheckpointer(args.ckpt_dir)
                        if args.ckpt_dir else None)

        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                loss = float(metrics["loss"])
                if math.isnan(loss):
                    raise SystemExit(310)   # NaN -> exit code for the engine
                dt = time.time() - t0
                tput = args.log_every * args.batch * args.seq / max(dt, 1e-9)
                print(f"[train] step {step+1}/{args.steps} "
                      f"loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                      f"grad_norm={float(metrics['grad_norm']):.2f} "
                      f"({tput:.0f} tok/s)", flush=True)
                t0 = time.time()
            if checkpointer and (step + 1) % args.ckpt_every == 0:
                checkpointer.save(step + 1, state)
        if checkpointer:
            checkpointer.save(args.steps, state)
            checkpointer.wait()
            print(f"[train] final checkpoint at {checkpointer.last_path}")


if __name__ == "__main__":
    main()
