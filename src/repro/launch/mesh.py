"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods of
    256 as (pod=2, data=16, model=16) — 'pod' is the DCN-connected axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_bf16_flops": 197e12,     # FLOP/s
    "hbm_bandwidth": 819e9,        # B/s
    "ici_link_bandwidth": 50e9,    # B/s per link
    "hbm_bytes": 16 * 1024**3,
    "dcn_bandwidth": 6.25e9,       # B/s per host (cross-pod axis)
}
