"""Logical-axis -> mesh-axis rules and PartitionSpec resolution.

The model code annotates parameters and activations with *logical* axis
names; this module maps them to physical mesh axes for a given mesh and
strategy. Key strategy knobs (the §Perf levers):

* ``fsdp``          — shard the ``embed`` parameter dim over the in-pod data
                      axis (FSDP). Off = paper-naive pure DP replication.
* ``fsdp_over_pod`` — additionally shard parameters over the cross-pod axis
                      (cheap DCN traffic trade-off; off by default).
* ``act_seq_shard`` — Megatron-style sequence sharding of the residual
                      stream between blocks.

Every resolved PartitionSpec is validated against the actual tensor shape:
a dim that does not divide evenly by its assigned mesh axes falls back to
replication for that dim (recorded so the dry-run can report it). This is
what makes e.g. the batch=1 ``long_500k`` cells lower cleanly.
"""

from __future__ import annotations

import math
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

AxisRule = Any   # str | tuple[str, ...] | None


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
               fsdp_over_pod: bool = False,
               act_seq_shard: bool = False,
               parallelism: str = "tp") -> dict[str, AxisRule]:
    """parallelism='tp' — model axis does tensor parallelism (baseline);
    parallelism='zero3' — both in-pod axes do data parallelism and every
    parameter is fully sharded on its embed dim (ZeRO-3 / pure-FSDP):
    weights are all-gathered layer-by-layer, activations never cross chips;
    parallelism='serve2d' — decode-optimised: weights stationary 2D
    (embed x data, heads/ffn x model), KV cache batch-sharded over data,
    decode activations replicated over data so GSPMD re-shards the (tiny)
    token activations instead of all-gathering 8 GB weight shards per step.
    """
    sizes = _mesh_sizes(mesh)
    model_size = sizes.get("model", 1)
    has_pod = "pod" in sizes

    if parallelism == "zero3":
        data_axes = (("pod", "data", "model") if has_pod
                     else ("data", "model"))
        shard_axes = ("data", "model")
        none_rules = {k: None for k in (
            "vocab", "heads", "kv_heads_w", "head_dim", "ffn",
            "ffn_sharded_w", "expert", "expert_sharded", "moe_ffn",
            "moe_ffn_act", "rnn_tp", "rnn_blocks", "xlstm_inner",
            "xlstm_hd", "xlstm_hd_out", "vocab_sharded", "heads_sharded",
            "kv_heads_sharded", "seq_sharded", "kv_seq_sharded",
            "ffn_sharded", "rnn_sharded", "xlstm_inner_sharded",
            "xlstm_hd_sharded", "act_seq", "act_seq_rnn")}
        return {
            "batch": data_axes,
            "kv_batch": data_axes,
            "moe_groups": data_axes,
            "layers": None,
            "embed": shard_axes,
            "embed_out": None,
            **none_rules,
        }

    data_axes = (("pod", "data") if has_pod else ("data",))
    if fsdp or parallelism == "serve2d":
        fsdp_axis: AxisRule = (("pod", "data") if (fsdp_over_pod and has_pod)
                               else ("data",))
    else:
        fsdp_axis = None

    heads_tp = cfg.attn_sharding == "heads"
    kv_w_shardable = heads_tp and cfg.num_kv_heads % model_size == 0
    ep = cfg.moe_sharding == "expert"

    serve2d = parallelism == "serve2d"
    rules: dict[str, AxisRule] = {
        # data-parallel dims. serve2d replicates decode activations over
        # data (tokens are tiny) while the KV cache stays batch-sharded.
        "batch": None if serve2d else data_axes,
        "kv_batch": data_axes,
        "moe_groups": None if serve2d else data_axes,
        # parameter dims
        "layers": None,
        "embed": fsdp_axis,
        "embed_out": None,
        "vocab": "model",
        "heads": "model" if heads_tp else None,
        "kv_heads_w": "model" if kv_w_shardable else None,
        "head_dim": None,
        "ffn": "model",
        "ffn_sharded_w": "model",
        "expert": None,                       # TP-in-expert: experts replicated
        "expert_sharded": "model" if ep else None,
        "moe_ffn": None if ep else "model",   # per-expert ffn weight dim
        "moe_ffn_act": None if ep else "model",
        "rnn_tp": "model",
        "rnn_blocks": "model",
        "xlstm_inner": "model",
        "xlstm_hd": None,
        "xlstm_hd_out": None,
        # activation dims
        "vocab_sharded": "model",
        "heads_sharded": "model" if heads_tp else None,
        "kv_heads_sharded": "model" if heads_tp else None,
        "seq_sharded": "model" if not heads_tp else None,
        "kv_seq_sharded": "model" if not heads_tp else None,
        "ffn_sharded": "model",
        "rnn_sharded": "model",
        "xlstm_inner_sharded": None,
        "xlstm_hd_sharded": None,
        "act_seq": "model" if act_seq_shard else None,
        "act_seq_rnn": "model" if act_seq_shard else None,
    }
    return rules


def _axes_to_names(rule: AxisRule) -> tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def resolve_spec(shape: Sequence[int], axes: Sequence[str | None],
                 rules: Mapping[str, AxisRule], sizes: Mapping[str, int],
                 notes: list[str] | None = None, name: str = "") -> P:
    """Resolve one tensor's logical axes to a PartitionSpec, dropping any
    assignment that does not divide the dim evenly."""
    parts: list[AxisRule] = []
    for dim, ax in zip(shape, axes):
        rule = rules.get(ax) if ax is not None else None
        names = _axes_to_names(rule)
        if names:
            prod = math.prod(sizes[n] for n in names)
            if dim % prod != 0:
                if notes is not None:
                    notes.append(
                        f"{name}: dim {dim} ∤ axes {names} (size {prod}); "
                        f"replicated instead")
                rule = None
        parts.append(rule if not isinstance(rule, tuple) else tuple(rule))
    return P(*parts)


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, str) or e is None for e in x)


def tree_partition_specs(shapes_tree: Any, axes_tree: Any,
                         rules: Mapping[str, AxisRule], mesh: Mesh,
                         notes: list[str] | None = None) -> Any:
    """PartitionSpec tree from parallel (shapes, logical axes) trees."""
    sizes = _mesh_sizes(mesh)

    def leaf(shape_leaf, axes_leaf):
        shp = (shape_leaf.shape if hasattr(shape_leaf, "shape")
               else tuple(shape_leaf))
        return resolve_spec(shp, axes_leaf, rules, sizes, notes)

    return jax.tree.map(leaf, shapes_tree, axes_tree,
                        is_leaf=lambda x: _is_axes_leaf(x) or
                        hasattr(x, "shape"))


def tree_named_shardings(shapes_tree: Any, axes_tree: Any,
                         rules: Mapping[str, AxisRule], mesh: Mesh,
                         notes: list[str] | None = None) -> Any:
    specs = tree_partition_specs(shapes_tree, axes_tree, rules, mesh, notes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
