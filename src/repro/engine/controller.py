"""Client-side process control (the ``verdi process pause|play|kill``
role, paper §III.C.b).

A :class:`ProcessController` is a synchronous facade over the broker's
control plane: control RPCs are routed by the broker to whichever daemon
worker owns ``process.<pk>``, and ``watch`` tails the
``state_changed.<pk>.<state>`` broadcast stream (with durable replay of
missed events). It is what the ``repro process`` CLI verbs and non-async
callers use; async code talks to :class:`repro.engine.broker.BrokerClient`
directly.
"""

from __future__ import annotations

from typing import Any, Iterator

from repro.engine.broker import SyncBrokerClient
from repro.engine.communicator import process_rpc_id


class NoRunningDaemon(RuntimeError):
    """No broker endpoint was found (daemon not running?)."""


class ProcessController:
    def __init__(self, host: str, port: int, timeout: float = 10.0):
        self.timeout = timeout
        try:
            self._client = SyncBrokerClient(host, port)
        except OSError as exc:
            raise NoRunningDaemon(
                f"cannot reach broker at {host}:{port}: {exc}") from exc

    @classmethod
    def from_workdir(cls, workdir: str, timeout: float = 10.0
                     ) -> "ProcessController":
        """Connect via the ``broker.json`` a running daemon wrote into its
        working directory."""
        import json
        import os

        path = os.path.join(workdir, "broker.json")
        if not os.path.exists(path):
            raise NoRunningDaemon(f"no broker.json in {workdir!r} — is the "
                                  "daemon running?")
        with open(path) as fh:
            info = json.load(fh)
        return cls(info["host"], info["port"], timeout=timeout)

    # -- control intents -----------------------------------------------------
    def _intent(self, pk: int, intent: str, **kw) -> Any:
        return self._client.rpc(process_rpc_id(pk), {"intent": intent, **kw},
                                timeout=self.timeout)

    def pause(self, pk: int) -> Any:
        return self._intent(pk, "pause")

    def play(self, pk: int) -> Any:
        return self._intent(pk, "play")

    def kill(self, pk: int, message: str = "killed by user") -> Any:
        return self._intent(pk, "kill", message=message)

    def status(self, pk: int) -> dict:
        return self._intent(pk, "status")

    # -- directory -----------------------------------------------------------
    def live_processes(self) -> list[int]:
        """pks with a live control endpoint right now (any worker)."""
        idents = self._client.lookup("process.*", timeout=self.timeout)
        return sorted(int(i.split(".", 1)[1]) for i in idents)

    def workers(self) -> list[dict]:
        """One status dict per connected daemon worker (advertised pks)."""
        out = []
        for ident in self._client.lookup("worker.*", timeout=self.timeout):
            try:
                out.append(self._client.rpc(ident, {}, timeout=self.timeout))
            except (KeyError, TimeoutError):
                continue
        return out

    # -- event tailing ---------------------------------------------------------
    def watch(self, pk: int | None = None, timeout: float | None = None,
              replay_since: int | None = None
              ) -> Iterator[tuple[str, Any, dict]]:
        """Yield live ``(subject, sender, body)`` state-change events —
        all processes, or one pk. Stops after ``timeout`` seconds total
        (None = tail forever)."""
        subject_filter = (f"state_changed.{pk}.*" if pk is not None
                          else "state_changed.*")
        yield from self._client.events(subject_filter=subject_filter,
                                       timeout=timeout,
                                       replay_since=replay_since)

    def close(self) -> None:
        self._client.close()

    def __enter__(self) -> "ProcessController":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
