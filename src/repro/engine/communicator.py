"""kiwiPy-style communicator (paper §III.C): task queues, RPC, broadcast.

``LocalCommunicator`` — in-process implementation with RabbitMQ-faithful
task-queue semantics: tasks are acknowledged only on successful completion;
un-acked tasks are redelivered (requeued) after a visibility timeout, which
is the in-process analogue of RabbitMQ's heartbeat-based requeue.

The cross-process implementation with durable (sqlite) queues lives in
``repro.engine.broker`` and exposes the same interface.
"""

from __future__ import annotations

import asyncio
import fnmatch
import itertools
import time
from typing import Any, Awaitable, Callable

RpcHandler = Callable[[dict], Any]
BroadcastHandler = Callable[[str, Any, dict], None]
TaskHandler = Callable[[dict], Awaitable[Any]]


class CommunicatorClosed(RuntimeError):
    pass


class LocalCommunicator:
    def __init__(self, *, requeue_timeout: float = 30.0):
        self._rpc: dict[str, RpcHandler] = {}
        self._broadcast: dict[int, tuple[str | None, BroadcastHandler]] = {}
        self._bc_counter = itertools.count()
        self._queues: dict[str, asyncio.Queue] = {}
        self._subscribers: dict[str, list[TaskHandler]] = {}
        self._consumers: dict[str, asyncio.Task] = {}
        self._inflight: dict[str, list[tuple[float, dict]]] = {}
        self.requeue_timeout = requeue_timeout
        self._closed = False

    # -- RPC -------------------------------------------------------------------
    def add_rpc_subscriber(self, identifier: str, handler: RpcHandler) -> None:
        self._rpc[identifier] = handler

    def remove_rpc_subscriber(self, identifier: str) -> None:
        self._rpc.pop(identifier, None)

    def rpc_send(self, identifier: str, msg: dict) -> Any:
        handler = self._rpc.get(identifier)
        if handler is None:
            raise KeyError(f"no RPC subscriber for {identifier!r}")
        return handler(msg)

    # -- broadcast ----------------------------------------------------------------
    def add_broadcast_subscriber(self, handler: BroadcastHandler,
                                 subject_filter: str | None = None) -> int:
        token = next(self._bc_counter)
        self._broadcast[token] = (subject_filter, handler)
        return token

    def remove_broadcast_subscriber(self, token: int) -> None:
        self._broadcast.pop(token, None)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        for subject_filter, handler in list(self._broadcast.values()):
            if subject_filter and not fnmatch.fnmatch(subject, subject_filter):
                continue
            try:
                handler(subject, sender, body or {})
            except Exception:  # noqa: BLE001 — subscribers cannot break engine
                import logging
                logging.getLogger("repro.engine").exception(
                    "broadcast subscriber failed")

    # -- task queues ------------------------------------------------------------------
    def _queue(self, name: str) -> asyncio.Queue:
        if name not in self._queues:
            self._queues[name] = asyncio.Queue()
            self._inflight[name] = []
        return self._queues[name]

    def task_send(self, queue: str, payload: dict) -> None:
        self._queue(queue).put_nowait(payload)

    def add_task_subscriber(self, queue: str, handler: TaskHandler) -> None:
        self._subscribers.setdefault(queue, []).append(handler)
        if queue not in self._consumers:
            self._consumers[queue] = asyncio.ensure_future(
                self._consume(queue))

    async def _consume(self, queue: str) -> None:
        q = self._queue(queue)
        while not self._closed:
            payload = await q.get()
            handlers = self._subscribers.get(queue, [])
            if not handlers:
                q.put_nowait(payload)
                await asyncio.sleep(0.05)
                continue
            handler = handlers[0]
            entry = (time.monotonic(), payload)
            self._inflight[queue].append(entry)
            try:
                await handler(payload)
                # success -> ack (drop from inflight)
                self._inflight[queue].remove(entry)
            except Exception:  # noqa: BLE001 — nack: requeue the task
                import logging
                logging.getLogger("repro.engine").exception(
                    "task handler failed; requeuing")
                self._inflight[queue].remove(entry)
                q.put_nowait(payload)
                await asyncio.sleep(0.1)

    def queue_depth(self, queue: str) -> int:
        return self._queue(queue).qsize()

    def close(self) -> None:
        self._closed = True
        for task in self._consumers.values():
            task.cancel()
