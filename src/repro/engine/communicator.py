"""kiwiPy-style communicator (paper §III.C): task queues, RPC, broadcast.

This module defines the *control-plane contract* every engine layer speaks:

* **RPC** — each live process subscribes under the identifier
  ``process.<pk>`` and accepts intent messages
  ``{"intent": "pause" | "play" | "kill" | "status"}`` (the legacy
  ``"action"`` key is accepted as an alias). Any client holding a
  communicator can therefore control any process, wherever it runs.
* **Broadcast** — every state transition is published under the subject
  ``state_changed.<pk>.<state>`` (e.g. ``state_changed.42.finished``);
  subscribers filter with fnmatch wildcards (``state_changed.42.*``,
  ``state_changed.*.killed``, …). Waiting on a process is therefore an
  event subscription, not a poll loop.
* **Task queues** — at-least-once delivery: tasks are acknowledged only on
  successful completion; un-acked tasks are redelivered after a
  visibility timeout (``requeue_timeout``), the in-process analogue of
  RabbitMQ's heartbeat-based requeue.

``LocalCommunicator`` is the in-process implementation. The cross-process
implementation with durable (sqlite) queues and RPC forwarding across OS
processes lives in ``repro.engine.broker`` and exposes the same interface.
"""

from __future__ import annotations

import asyncio
import fnmatch
import itertools
import time
from typing import Any, Awaitable, Callable

RpcHandler = Callable[[dict], Any]
BroadcastHandler = Callable[[str, Any, dict], None]
TaskHandler = Callable[[dict], Awaitable[Any]]

#: intents a process RPC subscriber must understand (paper §III.C.b)
CONTROL_INTENTS = ("pause", "play", "kill", "status")


def process_rpc_id(pk: int) -> str:
    """The RPC identifier a live process subscribes under."""
    return f"process.{pk}"


def state_subject(pk: int, state: str) -> str:
    """The broadcast subject for one process state transition."""
    return f"state_changed.{pk}.{state}"


def parse_state_subject(subject: str) -> tuple[int, str] | None:
    """Inverse of :func:`state_subject`; None for foreign subjects."""
    parts = subject.split(".")
    if len(parts) != 3 or parts[0] != "state_changed":
        return None
    try:
        return int(parts[1]), parts[2]
    except ValueError:
        return None


def control_intent(msg: dict) -> str | None:
    """Extract the intent from a control RPC message ('action' is the
    legacy alias)."""
    return msg.get("intent", msg.get("action"))


class CommunicatorClosed(RuntimeError):
    pass


class LocalCommunicator:
    """In-process communicator. ``requeue_timeout`` is a visibility
    timeout: size it above the longest legitimate handler runtime, or a
    slow-but-alive handler's task will be redelivered concurrently
    (at-least-once, like RabbitMQ). ``task_prefetch`` bounds concurrent
    handler invocations per queue. The daemon's process queue rides the
    broker, whose liveness signal is heartbeats, not this timeout."""

    def __init__(self, *, requeue_timeout: float = 30.0,
                 task_prefetch: int = 64):
        self._rpc: dict[str, RpcHandler] = {}
        self._broadcast: dict[int, tuple[str | None, BroadcastHandler]] = {}
        self._bc_counter = itertools.count()
        self._queues: dict[str, asyncio.Queue] = {}
        self._subscribers: dict[str, list[TaskHandler]] = {}
        self._subscribed: dict[str, asyncio.Event] = {}
        self._consumers: dict[str, asyncio.Task] = {}
        self._inflight: dict[str, list[dict]] = {}
        self._prefetch: dict[str, asyncio.Semaphore] = {}
        self._handler_tasks: set[asyncio.Future] = set()
        self._sweeper: asyncio.Task | None = None
        self.requeue_timeout = requeue_timeout
        self.task_prefetch = task_prefetch
        self._closed = False

    # -- RPC -------------------------------------------------------------------
    def add_rpc_subscriber(self, identifier: str, handler: RpcHandler) -> None:
        self._rpc[identifier] = handler

    def remove_rpc_subscriber(self, identifier: str) -> None:
        self._rpc.pop(identifier, None)

    def rpc_send(self, identifier: str, msg: dict,
                 timeout: float | None = None) -> Any:
        # ``timeout`` is interface parity with the broker clients; a local
        # handler is a direct call, so there is nothing to dead-line
        handler = self._rpc.get(identifier)
        if handler is None:
            raise KeyError(f"no RPC subscriber for {identifier!r}")
        return handler(msg)

    def rpc_identifiers(self, pattern: str = "*") -> list[str]:
        """Registered RPC identifiers matching an fnmatch pattern."""
        return sorted(i for i in self._rpc if fnmatch.fnmatch(i, pattern))

    # -- broadcast ----------------------------------------------------------------
    def add_broadcast_subscriber(self, handler: BroadcastHandler,
                                 subject_filter: str | None = None) -> int:
        token = next(self._bc_counter)
        self._broadcast[token] = (subject_filter, handler)
        return token

    def remove_broadcast_subscriber(self, token: int) -> None:
        self._broadcast.pop(token, None)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        for subject_filter, handler in list(self._broadcast.values()):
            if subject_filter and not fnmatch.fnmatch(subject, subject_filter):
                continue
            try:
                handler(subject, sender, body or {})
            except Exception:  # noqa: BLE001 — subscribers cannot break engine
                import logging
                logging.getLogger("repro.engine").exception(
                    "broadcast subscriber failed")

    # -- task queues ------------------------------------------------------------------
    def _queue(self, name: str) -> asyncio.Queue:
        if name not in self._queues:
            self._queues[name] = asyncio.Queue()
            self._inflight[name] = []
        return self._queues[name]

    def _subscribed_event(self, name: str) -> asyncio.Event:
        if name not in self._subscribed:
            self._subscribed[name] = asyncio.Event()
        return self._subscribed[name]

    def task_send(self, queue: str, payload: dict) -> None:
        self._queue(queue).put_nowait(payload)

    def task_send_many(self, queue: str, payloads: list[dict],
                       submitter: str | None = None) -> None:
        """Batch enqueue (interface parity with the broker clients; in
        process there is no syscall to amortize)."""
        q = self._queue(queue)
        for payload in payloads:
            q.put_nowait(payload)

    def add_task_subscriber(self, queue: str, handler: TaskHandler,
                            prefetch: int | None = None) -> None:
        if prefetch is not None:
            # per-queue override of the global prefetch bound
            self._prefetch.setdefault(queue, asyncio.Semaphore(prefetch))
        self._subscribers.setdefault(queue, []).append(handler)
        self._subscribed_event(queue).set()
        if queue not in self._consumers:
            self._consumers[queue] = asyncio.ensure_future(
                self._consume(queue))
        if self._sweeper is None:
            self._sweeper = asyncio.ensure_future(self._sweep_inflight())

    async def _consume(self, queue: str) -> None:
        q = self._queue(queue)
        sem = self._prefetch.setdefault(
            queue, asyncio.Semaphore(self.task_prefetch))
        while not self._closed:
            # no busy-requeue spin: park until someone subscribes
            await self._subscribed_event(queue).wait()
            # prefetch bound (RabbitMQ-style): at most ``task_prefetch``
            # handlers in flight per queue — backpressure for bursts,
            # while one hung handler still cannot stall the queue
            await sem.acquire()
            payload = await q.get()
            handlers = self._subscribers.get(queue, [])
            if not handlers:
                # no subscriber after all: park again instead of spinning
                sem.release()
                self._subscribed_event(queue).clear()
                q.put_nowait(payload)
                continue
            entry = {"t": time.monotonic(), "payload": payload,
                     "queue": queue}
            self._inflight[queue].append(entry)
            # dispatch concurrently so one hung handler cannot stall the
            # queue (and so the visibility-timeout sweeper has teeth);
            # track the future so close() can cancel in-flight handlers
            fut = asyncio.ensure_future(
                self._run_task(handlers[0], entry, sem))
            self._handler_tasks.add(fut)
            fut.add_done_callback(self._handler_tasks.discard)

    async def _run_task(self, handler: TaskHandler, entry: dict,
                        sem: asyncio.Semaphore) -> None:
        queue, payload = entry["queue"], entry["payload"]
        try:
            await handler(payload)
            self._ack(entry)            # success -> ack (drop from inflight)
        except Exception:  # noqa: BLE001 — nack: requeue the task
            import logging
            logging.getLogger("repro.engine").exception(
                "task handler failed; requeuing")
            if self._ack(entry):
                # throttle BEFORE requeueing: the concurrent dispatch loop
                # would otherwise spin a persistently-failing task
                await asyncio.sleep(0.1)
                self._queue(queue).put_nowait(payload)
        finally:
            sem.release()

    def _ack(self, entry: dict) -> bool:
        """Drop an entry from inflight; False if the sweeper already
        requeued it (redelivery in progress — at-least-once semantics)."""
        try:
            self._inflight[entry["queue"]].remove(entry)
            return True
        except (KeyError, ValueError):
            return False

    async def _sweep_inflight(self) -> None:
        """Visibility-timeout redelivery: a task whose handler has not
        acked within ``requeue_timeout`` is presumed hung and requeued
        (the in-process analogue of the broker's heartbeat reaper)."""
        interval = max(min(self.requeue_timeout / 4, 1.0), 0.01)
        while not self._closed:
            await asyncio.sleep(interval)
            deadline = time.monotonic() - self.requeue_timeout
            for queue, entries in self._inflight.items():
                for entry in [e for e in entries if e["t"] < deadline]:
                    entries.remove(entry)
                    import logging
                    logging.getLogger("repro.engine").warning(
                        "task in %r exceeded requeue_timeout; redelivering",
                        queue)
                    self._queue(queue).put_nowait(entry["payload"])

    def queue_depth(self, queue: str) -> int:
        return self._queue(queue).qsize()

    def close(self) -> None:
        self._closed = True
        for task in self._consumers.values():
            task.cancel()
        for fut in list(self._handler_tasks):
            fut.cancel()
        if self._sweeper is not None:
            self._sweeper.cancel()
