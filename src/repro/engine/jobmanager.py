"""Job manager (paper §II.B.4.c): bundles scheduler status queries.

Instead of each CalcJob polling the scheduler, jobs register an update
request; when a transport becomes available the manager issues ONE query
for all registered job ids and fans the answers back out. Combined with the
transport queue this keeps the scheduler load O(1) in the number of
concurrent jobs.
"""

from __future__ import annotations

import asyncio
from typing import Any

from repro.engine.transport import TransportQueue


class JobManager:
    def __init__(self, transport_queue: TransportQueue, scheduler,
                 hostname: str = "local", flush_interval: float = 0.05):
        self.transport_queue = transport_queue
        self.scheduler = scheduler
        self.hostname = hostname
        self.flush_interval = flush_interval
        self._requests: dict[str, list[asyncio.Future]] = {}
        self._flusher: asyncio.Task | None = None
        self.stats = {"requests": 0, "queries": 0}

    def request_job_state(self, job_id: str) -> asyncio.Future:
        """Register interest in a job's state; resolved at the next flush."""
        self.stats["requests"] += 1
        fut = asyncio.get_event_loop().create_future()
        self._requests.setdefault(job_id, []).append(fut)
        if self._flusher is None or self._flusher.done():
            self._flusher = asyncio.ensure_future(self._flush())
        return fut

    async def _flush(self) -> None:
        await asyncio.sleep(self.flush_interval)   # let requests bundle up
        if not self._requests:
            return
        pending, self._requests = self._requests, {}
        transport = await self.transport_queue.request_transport(self.hostname)
        self.stats["queries"] += 1
        try:
            states = await self.scheduler.query_jobs(
                transport, list(pending.keys()))
        except Exception as exc:  # noqa: BLE001 — fan the failure out
            for futs in pending.values():
                for f in futs:
                    if not f.done():
                        f.set_exception(exc)
            return
        for job_id, futs in pending.items():
            state = states.get(job_id, "UNDETERMINED")
            for f in futs:
                if not f.done():
                    f.set_result(state)
