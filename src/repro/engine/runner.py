"""The Runner (paper §III.A): event loop + persistence + communication +
transport, with vertical scaling via *process slots*.

A runner can drive any number of concurrent processes (bounded by its slot
count); the daemon (engine/daemon.py) scales horizontally by running one
runner per OS worker process.
"""

from __future__ import annotations

import asyncio
import logging
import time
from typing import Any, Iterable

from repro.core.exit_code import ExitCode
from repro.core.process import Process
from repro.observability import metrics as _metrics
from repro.observability import trace
from repro.engine.communicator import (
    LocalCommunicator, parse_state_subject, process_rpc_id,
)
from repro.core.statemachine import TERMINAL_STATES
from repro.provenance.store import (
    SUMMARY_COLUMNS, ProvenanceStore, current_store,
)

# derived from the canonical state-machine set — the single source of truth
TERMINAL = tuple(s.value for s in TERMINAL_STATES)

logger = logging.getLogger("repro.engine")


class ProcessHandle:
    def __init__(self, process: Process, task: asyncio.Task | None = None):
        self.process = process
        self.task = task

    @property
    def pk(self) -> int:
        return self.process.pk

    async def wait(self) -> ExitCode:
        await self.process.wait_done()
        return self.process.exit_code


class QueuedHandle:
    """Handle for a process shipped to the daemon via the task queue."""

    def __init__(self, pk: int):
        self.pk = pk


class Runner:
    def __init__(self, *, store: ProvenanceStore | None = None,
                 communicator=None, loop: asyncio.AbstractEventLoop | None = None,
                 slots: int = 200, liveness_interval: float = 30.0):
        self.store = store or current_store()
        self.communicator = communicator or LocalCommunicator()
        self._loop = loop
        self.slots = slots
        # NOT a poll interval: waits are event-driven; this only bounds how
        # often a waiter double-checks the store in case the owning worker
        # crashed without broadcasting a terminal state
        self.liveness_interval = liveness_interval
        # distinct submitter ids get fair (round-robin) dispatch at the
        # broker; None folds into the anonymous submitter lane
        self.submitter_id: str | None = None
        self.logger = logger
        self._processes: dict[int, ProcessHandle] = {}
        self._slot_sem: asyncio.Semaphore | None = None
        from repro.engine.transport import TransportQueue
        self.transport_queue = TransportQueue()

    # -- loop plumbing -----------------------------------------------------------
    @property
    def loop(self) -> asyncio.AbstractEventLoop:
        if self._loop is None:
            try:
                self._loop = asyncio.get_running_loop()
            except RuntimeError:
                self._loop = asyncio.new_event_loop()
                asyncio.set_event_loop(self._loop)
        return self._loop

    def _sem(self) -> asyncio.Semaphore:
        if self._slot_sem is None:
            self._slot_sem = asyncio.Semaphore(self.slots)
        return self._slot_sem

    # -- process control RPC (paper §III.C.b) ---------------------------------------
    def control(self, pk: int, intent: str, **kw) -> Any:
        """Send a control intent (pause/play/kill/status) to a live
        process. With a LocalCommunicator this returns the result; with a
        BrokerClient it returns an awaitable to ``await``."""
        return self.communicator.rpc_send(process_rpc_id(pk),
                                          {"intent": intent, **kw})

    # -- submission --------------------------------------------------------------------
    def submit(self, process_class, inputs: dict | None = None,
               parent_pk: int | None = None):
        """Instantiate + schedule a process (class or ProcessBuilder). In
        distributed (daemon) mode the process node + checkpoint are
        created locally but execution is shipped through the durable task
        queue, so any worker can pick it up (and resume it if that worker
        dies). Prefer the free functions in ``engine/launch.py`` — this is
        the underlying mechanism for explicit-runner use."""
        from repro.core.builder import expand_launch_target
        with trace.span("engine.submit"):
            process_class, inputs = expand_launch_target(process_class,
                                                         inputs)
            process = process_class(inputs=inputs, runner=self,
                                    parent_pk=parent_pk)
            _metrics.get_registry().counter("engine.submits").inc()
            if getattr(self, "distributed", False):
                from repro.engine.daemon import PROCESS_QUEUE
                # "ts" lets the picking worker measure queue latency;
                # "submitter" feeds the broker's fair-dispatch rotation
                payload = {"pk": process.pk, "ts": time.time()}
                if self.submitter_id is not None:
                    payload["submitter"] = self.submitter_id
                self.communicator.task_send(PROCESS_QUEUE, payload)
                return QueuedHandle(process.pk)
            return self._schedule(process)

    def _schedule(self, process: Process) -> ProcessHandle:
        # controllable from the moment of submission — even while queued
        # behind the slot semaphore (step_until_terminated re-registers
        # idempotently and unregisters on termination)
        process._register_control()

        async def _drive():
            async with self._sem():
                try:
                    return await process.step_until_terminated()
                finally:
                    self._processes.pop(process.pk, None)

        # create_task works on a not-yet-running loop; the task starts when
        # the loop does.
        task = self.loop.create_task(_drive())
        handle = ProcessHandle(process, task)
        self._processes[process.pk] = handle
        return handle

    def resume_from_checkpoint(self, pk: int,
                               epoch: int | None = None
                               ) -> ProcessHandle | None:
        """Recreate a process from its persisted checkpoint and schedule
        it. ``epoch`` (when resuming a broker-delivered task) is the lease
        fencing token the process stamps on every flush/terminal write."""
        checkpoint = self.store.load_checkpoint(pk)
        if checkpoint is None:
            return None
        process = Process.recreate_from_checkpoint(checkpoint, runner=self,
                                                   epoch=epoch)
        return self._schedule(process)

    # -- synchronous driving ---------------------------------------------------------
    def run_sync(self, process: Process) -> ExitCode:
        """Drive a process without suspending (process functions block the
        interpreter by design, §II.B.2). Works inside or outside a running
        event loop."""
        coro = process.step_until_terminated()
        try:
            coro.send(None)
        except StopIteration as stop:
            return stop.value
        coro.close()
        raise RuntimeError(
            f"{type(process).__name__} attempted a real asynchronous wait "
            "inside a synchronous (process function) context")

    def run(self, process_class, inputs: dict | None = None
            ) -> tuple[dict, Process]:
        """Blockingly run a process (class or ProcessBuilder) to
        completion on this runner's loop."""
        from repro.core.builder import expand_launch_target
        process_class, inputs = expand_launch_target(process_class, inputs)
        process = process_class(inputs=inputs, runner=self)
        if self.loop.is_running():
            raise RuntimeError("Runner.run() cannot be used inside a running "
                               "loop; use submit()")
        self.loop.run_until_complete(process.step_until_terminated())
        return process.outputs, process

    def run_until_complete(self, awaitable):
        return self.loop.run_until_complete(awaitable)

    # -- waiting on processes (local fast-path, remote purely event-driven) ----------
    async def wait_for_process(self, pk: int) -> None:
        """Block until the process is terminal. Local processes complete
        via their done-event; remote processes complete when their
        terminal ``state_changed.<pk>.<state>`` broadcast arrives — there
        is no poll loop, only a coarse liveness fallback that re-checks
        the store in case the owning worker crashed without broadcasting."""
        with trace.span("engine.wait", pk=pk):
            await self._wait_for_process(pk)

    async def _wait_for_process(self, pk: int) -> None:
        handle = self._processes.get(pk)
        if handle is not None:
            await handle.process.wait_done()
            return

        ev = asyncio.Event()
        loop = asyncio.get_running_loop()

        def on_broadcast(subject: str, sender, body):
            parsed = parse_state_subject(subject)
            if parsed and parsed[0] == pk and parsed[1] in TERMINAL:
                loop.call_soon_threadsafe(ev.set)

        # subscribe BEFORE the store check: a terminal broadcast landing
        # between check and subscribe would otherwise be lost
        token = self.communicator.add_broadcast_subscriber(
            on_broadcast, subject_filter=f"state_changed.{pk}.*")
        try:
            # with server-side filter pushdown the subscription is only
            # effective once the broker has processed it — barrier first,
            # then check the store, so no terminal event can fall between
            barrier = getattr(self.communicator, "subscription_barrier",
                              None)
            if barrier is not None:
                await barrier()
            node = self.store.get_node(pk, columns=SUMMARY_COLUMNS)
            if node and node.get("process_state") in TERMINAL:
                return
            while True:
                try:
                    await asyncio.wait_for(ev.wait(),
                                           timeout=self.liveness_interval)
                    return
                except asyncio.TimeoutError:
                    node = self.store.get_node(pk, columns=SUMMARY_COLUMNS)
                    if node and node.get("process_state") in TERMINAL:
                        return
        finally:
            self.communicator.remove_broadcast_subscriber(token)

    @staticmethod
    def _target_pk(target) -> int:
        return target if isinstance(target, int) else target.pk

    async def wait(self, target) -> dict | None:
        """Wait for a process (handle, queued handle or pk) to reach a
        terminal state; returns its final node row."""
        pk = self._target_pk(target)
        await self.wait_for_process(pk)
        return self.store.get_node(pk, columns=SUMMARY_COLUMNS)

    async def wait_all(self, targets: Iterable) -> list[dict | None]:
        """Wait for many processes concurrently (one broadcast
        subscription each, no serialization of the waits)."""
        return list(await asyncio.gather(
            *[self.wait(t) for t in targets]))

    def close(self) -> None:
        self.communicator.close()


_DEFAULT: Runner | None = None


def default_runner() -> Runner:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Runner()
    return _DEFAULT


def set_default_runner(runner: Runner | None) -> None:
    global _DEFAULT
    _DEFAULT = runner
