"""Transports and the transport queue (paper §II.B.4.b).

A *transport* is a connection to a compute resource (AiiDA: SSH to a login
node; here: the pod/cluster controller, or an in-process simulation). The
TransportQueue bundles connection requests per worker: it opens at most one
connection per ``safe_interval`` and hands the open transport to every
coroutine that queued a request — so N concurrent jobs cost O(1) connections
per interval instead of O(N).

Hardware adaptation note: inside a TPU pod there is no SSH rate limit; the
scarce serialized resource is the cluster-controller RPC channel and the
checkpoint-storage path, which is what the queue meters here (DESIGN.md §2).
"""

from __future__ import annotations

import asyncio
import time
from typing import Any, Callable

from repro.observability.metrics import StatsDict


class Transport:
    """Base transport: open/close + exec/put/get primitives."""

    def __init__(self, hostname: str = "local"):
        self.hostname = hostname
        self._open = False
        self.open_count = 0

    async def open(self) -> "Transport":
        self._open = True
        self.open_count += 1
        return self

    async def close(self) -> None:
        self._open = False

    @property
    def is_open(self) -> bool:
        return self._open

    # -- primitives (overridden by concrete transports) ----------------------
    async def exec_command(self, command: str) -> tuple[int, str, str]:
        raise NotImplementedError

    async def put_file(self, name: str, content: bytes) -> None:
        raise NotImplementedError

    async def get_file(self, name: str) -> bytes:
        raise NotImplementedError


class LocalTransport(Transport):
    """In-process transport with an in-memory filesystem per remote dir."""

    def __init__(self, hostname: str = "local"):
        super().__init__(hostname)
        self.files: dict[str, bytes] = {}
        self.commands: list[str] = []
        self.command_handler: Callable[[str], tuple[int, str, str]] | None = None

    async def exec_command(self, command: str) -> tuple[int, str, str]:
        assert self.is_open, "transport not open"
        self.commands.append(command)
        if self.command_handler is not None:
            return self.command_handler(command)
        return 0, "", ""

    async def put_file(self, name: str, content: bytes) -> None:
        assert self.is_open, "transport not open"
        self.files[name] = bytes(content)

    async def get_file(self, name: str) -> bytes:
        assert self.is_open, "transport not open"
        return self.files[name]


class FlakyTransport(LocalTransport):
    """Fault-injecting transport: fails the first N operations of each kind.
    Used by tests and the robustness benchmark to exercise the
    exponential-backoff machinery."""

    def __init__(self, fail_first: int = 2, hostname: str = "flaky"):
        super().__init__(hostname)
        self.fail_first = fail_first
        self._failures: dict[str, int] = {}

    def _maybe_fail(self, kind: str) -> None:
        n = self._failures.get(kind, 0)
        if n < self.fail_first:
            self._failures[kind] = n + 1
            raise ConnectionError(
                f"injected transport failure #{n + 1} for {kind}")

    async def exec_command(self, command: str):
        self._maybe_fail(f"exec:{command.split()[0]}")
        return await super().exec_command(command)

    async def put_file(self, name: str, content: bytes) -> None:
        self._maybe_fail("put")
        await super().put_file(name, content)

    async def get_file(self, name: str) -> bytes:
        self._maybe_fail("get")
        return await super().get_file(name)


class TransportRequest:
    """A pending request for an open transport."""

    def __init__(self) -> None:
        self.future: asyncio.Future = asyncio.get_event_loop().create_future()


class TransportQueue:
    """At most one connection opened per safe_interval per authinfo
    (paper §II.B.4.b). Requests issued while a transport is open share it."""

    def __init__(self, safe_interval: float = 0.05):
        self.safe_interval = safe_interval
        self._transports: dict[str, Transport] = {}
        self._last_open: dict[str, float] = {}
        self._locks: dict[str, asyncio.Lock] = {}
        self.stats = StatsDict("transport", {"requests": 0, "opens": 0})

    def register_transport(self, transport: Transport) -> None:
        self._transports[transport.hostname] = transport

    def _lock(self, host: str) -> asyncio.Lock:
        if host not in self._locks:
            self._locks[host] = asyncio.Lock()
        return self._locks[host]

    async def request_transport(self, hostname: str = "local") -> Transport:
        """Wait for the safe interval, open (or reuse) the connection."""
        self.stats["requests"] += 1
        transport = self._transports.get(hostname)
        if transport is None:
            transport = LocalTransport(hostname)
            self._transports[hostname] = transport
        async with self._lock(hostname):
            if transport.is_open:
                return transport
            now = time.monotonic()
            last = self._last_open.get(hostname, -1e9)
            wait = self.safe_interval - (now - last)
            if wait > 0:
                await asyncio.sleep(wait)
            await transport.open()
            self._last_open[hostname] = time.monotonic()
            self.stats["opens"] += 1
            return transport

    async def close_all(self) -> None:
        for t in self._transports.values():
            if t.is_open:
                await t.close()
