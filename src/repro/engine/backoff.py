"""Exponential-back-off-retry (paper §II.B.4.a).

Wraps any transport task in a coroutine that catches exceptions and
reschedules the operation with doubling intervals. After ``max_attempts``
the wrapper raises ``TransportTaskExhausted`` — the owning process then
PAUSES (never excepts), leaving the user free to fix the environment and
``play`` it (the paper's robustness contract).

Retries use *full jitter*: each wait is drawn uniformly from
``[0, interval]`` before the interval doubles. When hundreds of processes
hit the same dead scheduler at once, deterministic doubling re-synchronises
their retries into thundering herds — jitter decorrelates them. Pass
``jitter=False`` (or a seeded ``rng``) where tests need exact timings.
"""

from __future__ import annotations

import asyncio
import logging
import random
import time
from typing import Awaitable, Callable, TypeVar

_T = TypeVar("_T")

from repro.observability import metrics as _metrics

logger = logging.getLogger("repro.engine.backoff")


class TransportTaskExhausted(RuntimeError):
    def __init__(self, name: str, attempts: int, last: BaseException):
        super().__init__(
            f"transport task {name!r} failed {attempts} times; last error: "
            f"{last!r}")
        self.name = name
        self.attempts = attempts
        self.last = last


async def exponential_backoff_retry(
        fn: Callable[[], Awaitable],
        *, initial_interval: float = 0.2,
        max_attempts: int = 5,
        name: str = "transport-task",
        non_retryable: tuple[type[BaseException], ...] = (),
        sleeper: Callable[[float], Awaitable] | None = None,
        jitter: bool = True,
        rng: random.Random | None = None):
    """Run ``fn`` with exponential backoff: the interval ceiling doubles
    per retry; the actual wait is full-jittered within it."""
    sleep = sleeper or asyncio.sleep
    rand = rng or random
    interval = initial_interval
    last: BaseException | None = None
    registry = _metrics.get_registry()
    for attempt in range(1, max_attempts + 1):
        try:
            return await fn()
        except non_retryable:
            raise
        except asyncio.CancelledError:
            raise
        except Exception as exc:  # noqa: BLE001 — that's the point
            last = exc
            logger.warning("%s failed (attempt %d/%d): %r", name, attempt,
                           max_attempts, exc)
            if attempt == max_attempts:
                break
            registry.counter("backoff.retries").inc()
            await sleep(rand.uniform(0.0, interval) if jitter else interval)
            interval *= 2.0
    registry.counter("backoff.exhausted").inc()
    raise TransportTaskExhausted(name, max_attempts, last)


def retry_sync(
        fn: Callable[[], _T],
        *, initial_interval: float = 0.1,
        max_attempts: int = 5,
        name: str = "transport-task",
        non_retryable: tuple[type[BaseException], ...] = (),
        sleeper: Callable[[float], None] | None = None,
        jitter: bool = True,
        rng: random.Random | None = None) -> _T:
    """Blocking counterpart of :func:`exponential_backoff_retry` for the
    synchronous clients (CLI control verbs, daemon submitters). Same
    full-jitter schedule and the same ``backoff.*`` counters, so a broker
    restart window shows up identically in ``repro stats`` whichever
    transport crossed it."""
    sleep = sleeper or time.sleep
    rand = rng or random
    interval = initial_interval
    last: BaseException | None = None
    registry = _metrics.get_registry()
    for attempt in range(1, max_attempts + 1):
        try:
            return fn()
        except non_retryable:
            raise
        except Exception as exc:  # noqa: BLE001 — that's the point
            last = exc
            logger.warning("%s failed (attempt %d/%d): %r", name, attempt,
                           max_attempts, exc)
            if attempt == max_attempts:
                break
            registry.counter("backoff.retries").inc()
            sleep(rand.uniform(0.0, interval) if jitter else interval)
            interval *= 2.0
    registry.counter("backoff.exhausted").inc()
    raise TransportTaskExhausted(name, max_attempts, last)
