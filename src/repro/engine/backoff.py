"""Exponential-back-off-retry (paper §II.B.4.a).

Wraps any transport task in a coroutine that catches exceptions and
reschedules the operation with doubling intervals. After ``max_attempts``
the wrapper raises ``TransportTaskExhausted`` — the owning process then
PAUSES (never excepts), leaving the user free to fix the environment and
``play`` it (the paper's robustness contract)."""

from __future__ import annotations

import asyncio
import logging
from typing import Awaitable, Callable

logger = logging.getLogger("repro.engine.backoff")


class TransportTaskExhausted(RuntimeError):
    def __init__(self, name: str, attempts: int, last: BaseException):
        super().__init__(
            f"transport task {name!r} failed {attempts} times; last error: "
            f"{last!r}")
        self.name = name
        self.attempts = attempts
        self.last = last


async def exponential_backoff_retry(
        fn: Callable[[], Awaitable],
        *, initial_interval: float = 0.2,
        max_attempts: int = 5,
        name: str = "transport-task",
        non_retryable: tuple[type[BaseException], ...] = (),
        sleeper: Callable[[float], Awaitable] | None = None):
    """Run ``fn`` with exponential backoff: waits double per retry."""
    sleep = sleeper or asyncio.sleep
    interval = initial_interval
    last: BaseException | None = None
    for attempt in range(1, max_attempts + 1):
        try:
            return await fn()
        except non_retryable:
            raise
        except asyncio.CancelledError:
            raise
        except Exception as exc:  # noqa: BLE001 — that's the point
            last = exc
            logger.warning("%s failed (attempt %d/%d): %r", name, attempt,
                           max_attempts, exc)
            if attempt == max_attempts:
                break
            await sleep(interval)
            interval *= 2.0
    raise TransportTaskExhausted(name, max_attempts, last)
