"""Unified process launchers (paper §II.A; AiiDA 1.0 ``aiida.engine.launch``).

The one documented way to launch any process::

    from repro.engine.launch import run, run_get_node, run_get_pk, submit

    results = run(AddWorkChain, a=Int(1), b=Int(2))     # blocking
    results, node = run_get_node(builder)               # blocking, + node
    results, pk = run_get_pk(AddWorkChain, a=1, b=2)    # blocking, + pk
    handle = submit(builder)                            # non-blocking

Every launcher accepts either ``(ProcessClass, **inputs)`` or a
:class:`~repro.core.builder.ProcessBuilder` (keyword arguments override
builder values). ``run*`` drive the process to completion on the default
runner's loop; ``submit`` schedules it — on a distributed runner (daemon
worker) the process ships through the durable task queue to the worker
pool, otherwise it runs as a task on the local runner's loop.

``Runner.run``/``Runner.submit`` remain the underlying mechanism; use them
directly only when driving an explicit, non-default runner.
"""

from __future__ import annotations

from collections import namedtuple
from typing import Any, Mapping

from repro.core.builder import expand_launch_target
from repro.core.process import Process

ResultAndNode = namedtuple("ResultAndNode", ["results", "node"])
ResultAndPk = namedtuple("ResultAndPk", ["results", "pk"])


def _default_runner():
    from repro.engine.runner import default_runner
    return default_runner()


def _expand(process, inputs, kwargs):
    """Combine the positional inputs dict and keyword inputs, then expand:
    both override-styles flow through the same builder-merge semantics."""
    overrides = dict(inputs or {})
    overrides.update(kwargs)
    return expand_launch_target(process, overrides)


def run(process, inputs: Mapping[str, Any] | None = None, *,
        runner=None, **kwargs) -> dict[str, Any]:
    """Run a process to completion, blocking; returns its outputs."""
    return run_get_node(process, inputs, runner=runner, **kwargs).results


def run_get_node(process, inputs: Mapping[str, Any] | None = None, *,
                 runner=None, **kwargs) -> ResultAndNode:
    """Run a process to completion, blocking; returns ``(outputs,
    process)`` — the process object doubles as the provenance node view
    (``.pk``, ``.exit_code``, ``.is_finished_ok``)."""
    process_class, merged = _expand(process, inputs, kwargs)
    runner = runner or _default_runner()
    outputs, node = runner.run(process_class, merged)
    return ResultAndNode(outputs, node)


def run_get_pk(process, inputs: Mapping[str, Any] | None = None, *,
               runner=None, **kwargs) -> ResultAndPk:
    """Run a process to completion, blocking; returns ``(outputs, pk)``."""
    results, node = run_get_node(process, inputs, runner=runner, **kwargs)
    return ResultAndPk(results, node.pk)


def submit(process, inputs: Mapping[str, Any] | None = None, *,
           runner=None, **kwargs):
    """Schedule a process without waiting. Returns a handle with ``.pk``:
    a ``ProcessHandle`` on a local runner, a ``QueuedHandle`` when the
    runner is distributed and the process was shipped to the daemon's
    task queue (paper §III.C.a)."""
    process_class, merged = _expand(process, inputs, kwargs)
    runner = runner or _default_runner()
    return runner.submit(process_class, inputs=merged)


def instantiate(process, inputs: Mapping[str, Any] | None = None, *,
                runner=None, **kwargs) -> Process:
    """Construct (but do not schedule) a process: node + input links +
    initial checkpoint are created, so the pk can be shipped anywhere."""
    process_class, merged = _expand(process, inputs, kwargs)
    return process_class(inputs=merged, runner=runner or _default_runner())
