"""Durable cross-process message broker (the RabbitMQ role, paper §III.C).

A small asyncio TCP server backed by sqlite gives the three messaging
patterns with RabbitMQ-faithful guarantees:

* **task queues** — persistent messages (survive broker restarts), explicit
  acks, per-consumer heartbeats: a consumer that misses ``2 × heartbeat``
  is presumed dead and its un-acked tasks are requeued (paper: "upon
  missing two consecutive responses, RabbitMQ assumes the worker to be
  dead and triggers the rescheduling mechanism").
* **RPC** — request/response routed by subscriber identifier.
* **broadcast** — fan-out to all connected clients.

Protocol: newline-delimited JSON over TCP (loopback). This is deliberately
boring; the durability lives in sqlite (WAL), the liveness in heartbeats.
"""

from __future__ import annotations

import asyncio
import itertools
import json
import logging
import os
import sqlite3
import time
import uuid
from typing import Any, Awaitable, Callable

logger = logging.getLogger("repro.engine.broker")

_TASKS_SCHEMA = """
CREATE TABLE IF NOT EXISTS tasks (
    id INTEGER PRIMARY KEY AUTOINCREMENT,
    queue TEXT NOT NULL,
    payload TEXT NOT NULL,
    state TEXT NOT NULL DEFAULT 'ready',   -- ready | inflight | done
    consumer TEXT,
    delivered_at REAL,
    created_at REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_tasks_queue ON tasks(queue, state);
"""


class BrokerServer:
    """The broker daemon. One per deployment (like one RabbitMQ service)."""

    def __init__(self, db_path: str, host: str = "127.0.0.1", port: int = 0,
                 heartbeat: float = 5.0):
        self.db_path = db_path
        self.host = host
        self.port = port
        self.heartbeat = heartbeat
        self._server: asyncio.AbstractServer | None = None
        self._clients: dict[str, asyncio.StreamWriter] = {}
        self._consumers: dict[str, set[str]] = {}      # queue -> client ids
        self._rpc: dict[str, str] = {}                 # identifier -> client id
        self._last_beat: dict[str, float] = {}
        self._pending_rpc: dict[str, tuple[str, Any]] = {}
        self._conn = None

    # -- storage ------------------------------------------------------------
    def conn(self) -> sqlite3.Connection:
        if self._conn is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.db_path)),
                        exist_ok=True)
            self._conn = sqlite3.connect(self.db_path)
            self._conn.row_factory = sqlite3.Row
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.executescript(_TASKS_SCHEMA)
            self._conn.commit()
        return self._conn

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> tuple[str, int]:
        self._server = await asyncio.start_server(self._on_client, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        asyncio.ensure_future(self._reaper())
        logger.info("broker listening on %s:%d", self.host, self.port)
        return self.host, self.port

    async def stop(self) -> None:
        if self._server:
            self._server.close()
            await self._server.wait_closed()

    # -- client handling ---------------------------------------------------------
    async def _on_client(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        cid = str(uuid.uuid4())
        self._clients[cid] = writer
        self._last_beat[cid] = time.monotonic()
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except json.JSONDecodeError:
                    continue
                await self._handle(cid, msg)
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._drop_client(cid)

    def _drop_client(self, cid: str) -> None:
        self._clients.pop(cid, None)
        self._last_beat.pop(cid, None)
        for consumers in self._consumers.values():
            consumers.discard(cid)
        for ident in [k for k, v in self._rpc.items() if v == cid]:
            del self._rpc[ident]
        # requeue this consumer's inflight tasks immediately...
        self.conn().execute(
            "UPDATE tasks SET state='ready', consumer=NULL WHERE "
            "state='inflight' AND consumer=?", (cid,))
        self.conn().commit()
        # ...and push them to surviving/new consumers right away
        for queue in list(self._consumers):
            self._deliver(queue)

    def _send(self, cid: str, msg: dict) -> None:
        writer = self._clients.get(cid)
        if writer is None:
            return
        try:
            writer.write(json.dumps(msg).encode() + b"\n")
        except Exception:  # noqa: BLE001
            self._drop_client(cid)

    # -- message dispatch ------------------------------------------------------------
    async def _handle(self, cid: str, msg: dict) -> None:
        kind = msg.get("kind")
        if kind == "heartbeat":
            self._last_beat[cid] = time.monotonic()
        elif kind == "task_send":
            self.conn().execute(
                "INSERT INTO tasks (queue, payload, created_at)"
                " VALUES (?,?,?)",
                (msg["queue"], json.dumps(msg["payload"]), time.time()))
            self.conn().commit()
            self._deliver(msg["queue"])
        elif kind == "consume":
            self._consumers.setdefault(msg["queue"], set()).add(cid)
            self._deliver(msg["queue"])
        elif kind == "ack":
            self.conn().execute(
                "UPDATE tasks SET state='done' WHERE id=?", (msg["task_id"],))
            self.conn().commit()
            # deliver further work to this consumer
            for queue, members in self._consumers.items():
                if cid in members:
                    self._deliver(queue)
        elif kind == "nack":
            self.conn().execute(
                "UPDATE tasks SET state='ready', consumer=NULL WHERE id=?",
                (msg["task_id"],))
            self.conn().commit()
            self._deliver(msg["queue"])
        elif kind == "rpc_register":
            self._rpc[msg["identifier"]] = cid
        elif kind == "rpc_send":
            target = self._rpc.get(msg["identifier"])
            if target is None:
                self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                                 "error": f"no subscriber "
                                          f"{msg['identifier']!r}"})
            else:
                self._pending_rpc[msg["rid"]] = (cid, None)
                self._send(target, {"kind": "rpc_request", "rid": msg["rid"],
                                    "identifier": msg["identifier"],
                                    "msg": msg["msg"]})
        elif kind == "rpc_reply":
            origin = self._pending_rpc.pop(msg["rid"], None)
            if origin is not None:
                self._send(origin[0], msg)
        elif kind == "broadcast":
            for other in list(self._clients):
                self._send(other, {"kind": "broadcast",
                                   "subject": msg["subject"],
                                   "sender": msg.get("sender"),
                                   "body": msg.get("body", {})})

    # -- delivery ---------------------------------------------------------------------
    def _deliver(self, queue: str) -> None:
        consumers = [c for c in self._consumers.get(queue, set())
                     if c in self._clients]
        if not consumers:
            return
        # round-robin ready tasks to consumers with capacity (prefetch=1
        # per delivery round, like a fair RabbitMQ dispatch)
        rows = self.conn().execute(
            "SELECT id, payload FROM tasks WHERE queue=? AND state='ready'"
            " ORDER BY id", (queue,)).fetchall()
        inflight = {
            r["consumer"]: r["c"] for r in self.conn().execute(
                "SELECT consumer, COUNT(*) c FROM tasks WHERE queue=? AND"
                " state='inflight' GROUP BY consumer", (queue,))}
        ring = itertools.cycle(consumers)
        for row in rows:
            target = None
            for _ in range(len(consumers)):
                cand = next(ring)
                if inflight.get(cand, 0) < 1:
                    target = cand
                    break
            if target is None:
                break
            self.conn().execute(
                "UPDATE tasks SET state='inflight', consumer=?, delivered_at=?"
                " WHERE id=?", (target, time.time(), row["id"]))
            inflight[target] = inflight.get(target, 0) + 1
            self._send(target, {"kind": "task", "queue": queue,
                                "task_id": row["id"],
                                "payload": json.loads(row["payload"])})
        self.conn().commit()

    # -- liveness ----------------------------------------------------------------------
    async def _reaper(self) -> None:
        """Requeue tasks of consumers that missed two heartbeats."""
        while True:
            await asyncio.sleep(self.heartbeat)
            deadline = time.monotonic() - 2 * self.heartbeat
            dead = [cid for cid, beat in self._last_beat.items()
                    if beat < deadline]
            for cid in dead:
                logger.warning("consumer %s missed heartbeats; requeueing",
                               cid[:8])
                writer = self._clients.get(cid)
                if writer is not None:
                    writer.close()
                self._drop_client(cid)
            if dead:
                for queue in list(self._consumers):
                    self._deliver(queue)


class BrokerClient:
    """Communicator-compatible client for the broker (kiwiPy role).

    Runs its protocol on the caller's event loop; heartbeats are sent from
    a background task so a busy worker still responds (kiwiPy runs a
    separate thread for the same reason — see paper §III.C.a)."""

    def __init__(self, host: str, port: int):
        self.host = host
        self.port = port
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._rpc_handlers: dict[str, Callable] = {}
        self._task_handlers: dict[str, Callable[[dict], Awaitable]] = {}
        self._broadcast_handlers: dict[int, tuple[str | None, Callable]] = {}
        self._bc_counter = itertools.count()
        self._rpc_waiters: dict[str, asyncio.Future] = {}
        self._tasks: list[asyncio.Task] = []
        self.heartbeat = 1.0

    async def connect(self) -> None:
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        # re-register any existing subscriptions (reconnect path)
        for identifier in self._rpc_handlers:
            self._send({"kind": "rpc_register", "identifier": identifier})
        for queue in self._task_handlers:
            self._send({"kind": "consume", "queue": queue})
        if not self._tasks:
            self._tasks.append(asyncio.ensure_future(self._recv_loop()))
            self._tasks.append(asyncio.ensure_future(self._heartbeat_loop()))

    def _send(self, msg: dict) -> None:
        if self._writer is None or self._writer.is_closing():
            return
        try:
            self._writer.write(json.dumps(msg).encode() + b"\n")
        except Exception:  # noqa: BLE001 — reconnect loop will recover
            pass

    async def _heartbeat_loop(self) -> None:
        while True:
            self._send({"kind": "heartbeat"})
            await asyncio.sleep(self.heartbeat)

    async def _reconnect(self) -> None:
        delay = 0.2
        while True:
            try:
                await self.connect()
                logger.info("broker client reconnected")
                return
            except OSError:
                await asyncio.sleep(delay)
                delay = min(delay * 2, 5.0)

    async def _recv_loop(self) -> None:
        while True:
            assert self._reader is not None
            line = await self._reader.readline()
            if not line:
                # connection lost (e.g. broker reaped us while busy, or
                # broker restarted): reconnect and resubscribe
                if self._writer is not None:
                    self._writer.close()
                self._reader = self._writer = None
                await self._reconnect()
                continue
            msg = json.loads(line)
            kind = msg.get("kind")
            if kind == "task":
                asyncio.ensure_future(self._run_task(msg))
            elif kind == "rpc_request":
                await self._run_rpc(msg)
            elif kind == "rpc_reply":
                fut = self._rpc_waiters.pop(msg["rid"], None)
                if fut and not fut.done():
                    if "error" in msg:
                        fut.set_exception(KeyError(msg["error"]))
                    else:
                        fut.set_result(msg.get("result"))
            elif kind == "broadcast":
                import fnmatch
                for filt, handler in list(self._broadcast_handlers.values()):
                    if filt and not fnmatch.fnmatch(msg["subject"], filt):
                        continue
                    try:
                        handler(msg["subject"], msg.get("sender"),
                                msg.get("body", {}))
                    except Exception:  # noqa: BLE001
                        logger.exception("broadcast handler failed")

    async def _run_task(self, msg: dict) -> None:
        handler = self._task_handlers.get(msg["queue"])
        if handler is None:
            self._send({"kind": "nack", "task_id": msg["task_id"],
                        "queue": msg["queue"]})
            return
        try:
            await handler(msg["payload"])
            self._send({"kind": "ack", "task_id": msg["task_id"]})
        except Exception:  # noqa: BLE001
            logger.exception("task failed; nacking for requeue")
            self._send({"kind": "nack", "task_id": msg["task_id"],
                        "queue": msg["queue"]})

    async def _run_rpc(self, msg: dict) -> None:
        handler = self._rpc_handlers.get(msg["identifier"])
        reply: dict = {"kind": "rpc_reply", "rid": msg["rid"]}
        if handler is None:
            reply["error"] = f"no handler {msg['identifier']!r}"
        else:
            try:
                res = handler(msg["msg"])
                if asyncio.iscoroutine(res):
                    res = await res
                reply["result"] = res
            except Exception as exc:  # noqa: BLE001
                reply["error"] = repr(exc)
        self._send(reply)

    # -- Communicator interface ---------------------------------------------------
    def add_rpc_subscriber(self, identifier: str, handler: Callable) -> None:
        self._rpc_handlers[identifier] = handler
        self._send({"kind": "rpc_register", "identifier": identifier})

    def remove_rpc_subscriber(self, identifier: str) -> None:
        self._rpc_handlers.pop(identifier, None)

    async def rpc_send_async(self, identifier: str, msg: dict) -> Any:
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        self._send({"kind": "rpc_send", "rid": rid, "identifier": identifier,
                    "msg": msg})
        return await fut

    def rpc_send(self, identifier: str, msg: dict) -> Any:
        return self.rpc_send_async(identifier, msg)

    def add_broadcast_subscriber(self, handler: Callable,
                                 subject_filter: str | None = None) -> int:
        token = next(self._bc_counter)
        self._broadcast_handlers[token] = (subject_filter, handler)
        return token

    def remove_broadcast_subscriber(self, token: int) -> None:
        self._broadcast_handlers.pop(token, None)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        self._send({"kind": "broadcast", "subject": subject,
                    "sender": sender, "body": body or {}})

    def task_send(self, queue: str, payload: dict) -> None:
        self._send({"kind": "task_send", "queue": queue, "payload": payload})

    def add_task_subscriber(self, queue: str,
                            handler: Callable[[dict], Awaitable]) -> None:
        self._task_handlers[queue] = handler
        self._send({"kind": "consume", "queue": queue})

    def close(self) -> None:
        for t in self._tasks:
            t.cancel()
        if self._writer is not None:
            self._writer.close()
