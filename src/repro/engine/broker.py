"""Durable cross-process message broker (the RabbitMQ role, paper §III.C).

A small asyncio TCP server backed by sqlite gives the three messaging
patterns with RabbitMQ-faithful guarantees:

* **task queues** — persistent messages (survive broker restarts), explicit
  acks, per-consumer heartbeats: a consumer that misses ``2 × heartbeat``
  is presumed dead and its un-acked tasks are requeued (paper: "upon
  missing two consecutive responses, RabbitMQ assumes the worker to be
  dead and triggers the rescheduling mechanism").
* **RPC** — request/response routed by subscriber identifier, forwarded
  across OS processes: any client can reach ``process.<pk>`` wherever the
  owning worker runs (paper §III.C.b). ``rpc_lookup`` queries the live
  identifier directory, which is how workers advertise the pks they own.
* **broadcast** — fan-out to all connected clients, durably: every event
  is appended to a sqlite log with a monotonic sequence number, and a
  client can replay missed events with ``events_since`` (so a watcher
  that reconnects sees what happened while it was away).

Protocol: newline-delimited JSON over TCP (loopback). This is deliberately
boring; the durability lives in sqlite (WAL), the liveness in heartbeats.
"""

from __future__ import annotations

import asyncio
import fnmatch
import itertools
import json
import logging
import os
import socket
import sqlite3
import time
import uuid
from typing import Any, Awaitable, Callable, Iterator

from repro.observability import metrics as _metrics
from repro.observability import trace

logger = logging.getLogger("repro.engine.broker")

_TASKS_SCHEMA = """
CREATE TABLE IF NOT EXISTS tasks (
    id INTEGER PRIMARY KEY AUTOINCREMENT,
    queue TEXT NOT NULL,
    payload TEXT NOT NULL,
    state TEXT NOT NULL DEFAULT 'ready',   -- ready | inflight | done
    consumer TEXT,
    delivered_at REAL,
    created_at REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_tasks_queue ON tasks(queue, state);
CREATE TABLE IF NOT EXISTS events (
    seq INTEGER PRIMARY KEY AUTOINCREMENT,
    subject TEXT NOT NULL,
    sender TEXT,
    body TEXT NOT NULL,
    ts REAL NOT NULL
);
"""

#: keep at most this many events in the durable broadcast log
EVENT_LOG_CAP = 10000


class BrokerServer:
    """The broker daemon. One per deployment (like one RabbitMQ service)."""

    def __init__(self, db_path: str, host: str = "127.0.0.1", port: int = 0,
                 heartbeat: float = 5.0):
        self.db_path = db_path
        self.host = host
        self.port = port
        self.heartbeat = heartbeat
        self._server: asyncio.AbstractServer | None = None
        self._clients: dict[str, asyncio.StreamWriter] = {}
        self._consumers: dict[str, set[str]] = {}      # queue -> client ids
        self._rpc: dict[str, str] = {}                 # identifier -> client id
        self._last_beat: dict[str, float] = {}
        self._pending_rpc: dict[str, tuple[str, Any]] = {}
        self._events_uncommitted = 0
        self._conn = None
        self._reaper_task: asyncio.Task | None = None

    # -- storage ------------------------------------------------------------
    def conn(self) -> sqlite3.Connection:
        if self._conn is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.db_path)),
                        exist_ok=True)
            self._conn = sqlite3.connect(self.db_path)
            self._conn.row_factory = sqlite3.Row
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.executescript(_TASKS_SCHEMA)
            self._conn.commit()
        return self._conn

    # -- lifecycle -----------------------------------------------------------
    async def start(self) -> tuple[str, int]:
        self._server = await asyncio.start_server(self._on_client, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._reaper_task = asyncio.ensure_future(self._reaper())
        logger.info("broker listening on %s:%d", self.host, self.port)
        return self.host, self.port

    async def stop(self) -> None:
        if self._reaper_task is not None:
            self._reaper_task.cancel()
            self._reaper_task = None
        # closing the writers EOFs each _on_client loop so the per-client
        # handler tasks finish instead of lingering past the server
        for writer in list(self._clients.values()):
            writer.close()
        self._clients.clear()
        self._last_beat.clear()
        if self._events_uncommitted and self._conn is not None:
            self._conn.commit()
            self._events_uncommitted = 0
        if self._server:
            self._server.close()
            await self._server.wait_closed()
        await asyncio.sleep(0)  # let client tasks observe the EOF
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    # -- client handling ---------------------------------------------------------
    async def _on_client(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        cid = str(uuid.uuid4())
        self._clients[cid] = writer
        self._last_beat[cid] = time.monotonic()
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except json.JSONDecodeError:
                    continue
                await self._handle(cid, msg)
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._drop_client(cid)

    def _drop_client(self, cid: str) -> None:
        self._clients.pop(cid, None)
        self._last_beat.pop(cid, None)
        for consumers in self._consumers.values():
            consumers.discard(cid)
        for ident in [k for k, v in self._rpc.items() if v == cid]:
            del self._rpc[ident]
        # fail RPCs whose target just died — callers must not hang forever
        for rid in [r for r, (_, target) in self._pending_rpc.items()
                    if target == cid]:
            origin, _ = self._pending_rpc.pop(rid)
            self._send(origin, {"kind": "rpc_reply", "rid": rid,
                                "error": "rpc target disconnected"})
        # requeue this consumer's inflight tasks immediately...
        self.conn().execute(
            "UPDATE tasks SET state='ready', consumer=NULL WHERE "
            "state='inflight' AND consumer=?", (cid,))
        self.conn().commit()
        # ...and push them to surviving/new consumers right away
        for queue in list(self._consumers):
            self._deliver(queue)

    def _send(self, cid: str, msg: dict) -> None:
        writer = self._clients.get(cid)
        if writer is None:
            return
        if writer.is_closing():
            self._drop_client(cid)
            return
        try:
            writer.write(json.dumps(msg).encode() + b"\n")
        except Exception:  # noqa: BLE001
            self._drop_client(cid)

    # -- message dispatch ------------------------------------------------------------
    async def _handle(self, cid: str, msg: dict) -> None:
        kind = msg.get("kind")
        if kind == "heartbeat":
            self._last_beat[cid] = time.monotonic()
        elif kind == "task_send":
            self.conn().execute(
                "INSERT INTO tasks (queue, payload, created_at)"
                " VALUES (?,?,?)",
                (msg["queue"], json.dumps(msg["payload"]), time.time()))
            self.conn().commit()
            self._deliver(msg["queue"])
        elif kind == "consume":
            self._consumers.setdefault(msg["queue"], set()).add(cid)
            self._deliver(msg["queue"])
        elif kind == "ack":
            self.conn().execute(
                "UPDATE tasks SET state='done' WHERE id=?", (msg["task_id"],))
            self.conn().commit()
            # deliver further work to this consumer
            for queue, members in self._consumers.items():
                if cid in members:
                    self._deliver(queue)
        elif kind == "nack":
            self.conn().execute(
                "UPDATE tasks SET state='ready', consumer=NULL WHERE id=?",
                (msg["task_id"],))
            self.conn().commit()
            self._deliver(msg["queue"])
        elif kind == "rpc_register":
            self._rpc[msg["identifier"]] = cid
        elif kind == "rpc_unregister":
            if self._rpc.get(msg["identifier"]) == cid:
                del self._rpc[msg["identifier"]]
        elif kind == "rpc_lookup":
            # the live-identifier directory: how clients discover which
            # processes/workers are reachable right now
            pattern = msg.get("pattern", "*")
            self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                             "result": sorted(
                                 i for i in self._rpc
                                 if fnmatch.fnmatch(i, pattern))})
        elif kind == "rpc_send":
            target = self._rpc.get(msg["identifier"])
            if target is None:
                self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                                 "error": f"no subscriber "
                                          f"{msg['identifier']!r}"})
            else:
                self._pending_rpc[msg["rid"]] = (cid, target)
                self._send(target, {"kind": "rpc_request", "rid": msg["rid"],
                                    "identifier": msg["identifier"],
                                    "msg": msg["msg"]})
        elif kind == "rpc_reply":
            origin = self._pending_rpc.pop(msg["rid"], None)
            if origin is not None:
                self._send(origin[0], msg)
        elif kind == "broadcast":
            seq = self._log_event(msg)
            for other in list(self._clients):
                self._send(other, {"kind": "broadcast", "seq": seq,
                                   "subject": msg["subject"],
                                   "sender": msg.get("sender"),
                                   "body": msg.get("body", {})})
        elif kind == "events_since":
            # durable replay: stream the logged events this client missed
            pattern = msg.get("pattern")
            rows = self.conn().execute(
                "SELECT seq, subject, sender, body FROM events WHERE seq>?"
                " ORDER BY seq", (msg.get("seq", 0),)).fetchall()
            last = msg.get("seq", 0)
            for row in rows:
                last = row["seq"]
                if pattern and not fnmatch.fnmatch(row["subject"], pattern):
                    continue
                self._send(cid, {"kind": "broadcast", "seq": row["seq"],
                                 "subject": row["subject"],
                                 "sender": json.loads(row["sender"] or "null"),
                                 "body": json.loads(row["body"]),
                                 "replay": True})
            self._send(cid, {"kind": "events_caught_up", "seq": last})

    def _log_event(self, msg: dict) -> int:
        """Append a broadcast to the durable event log; returns its seq.
        Commits are batched (every 50 events + the reaper tick): replay
        reads go through the same connection and therefore see uncommitted
        rows, so fan-out latency never waits on fsync."""
        conn = self.conn()
        cur = conn.execute(
            "INSERT INTO events (subject, sender, body, ts) VALUES (?,?,?,?)",
            (msg["subject"], json.dumps(msg.get("sender")),
             json.dumps(msg.get("body", {})), time.time()))
        seq = cur.lastrowid
        if seq % 1000 == 0:
            conn.execute("DELETE FROM events WHERE seq <= ?",
                         (seq - EVENT_LOG_CAP,))
        self._events_uncommitted += 1
        if self._events_uncommitted >= 50:
            conn.commit()
            self._events_uncommitted = 0
        return seq

    # -- delivery ---------------------------------------------------------------------
    def _deliver(self, queue: str) -> None:
        consumers = [c for c in self._consumers.get(queue, set())
                     if c in self._clients]
        if not consumers:
            return
        # round-robin ready tasks to consumers with capacity (prefetch=1
        # per delivery round, like a fair RabbitMQ dispatch)
        rows = self.conn().execute(
            "SELECT id, payload FROM tasks WHERE queue=? AND state='ready'"
            " ORDER BY id", (queue,)).fetchall()
        inflight = {
            r["consumer"]: r["c"] for r in self.conn().execute(
                "SELECT consumer, COUNT(*) c FROM tasks WHERE queue=? AND"
                " state='inflight' GROUP BY consumer", (queue,))}
        ring = itertools.cycle(consumers)
        for row in rows:
            target = None
            for _ in range(len(consumers)):
                cand = next(ring)
                if inflight.get(cand, 0) < 1:
                    target = cand
                    break
            if target is None:
                break
            self.conn().execute(
                "UPDATE tasks SET state='inflight', consumer=?, delivered_at=?"
                " WHERE id=?", (target, time.time(), row["id"]))
            inflight[target] = inflight.get(target, 0) + 1
            self._send(target, {"kind": "task", "queue": queue,
                                "task_id": row["id"],
                                "payload": json.loads(row["payload"])})
        self.conn().commit()

    # -- liveness ----------------------------------------------------------------------
    async def _reaper(self) -> None:
        """Requeue tasks of consumers that missed two heartbeats."""
        while True:
            await asyncio.sleep(self.heartbeat)
            if self._events_uncommitted:
                self.conn().commit()
                self._events_uncommitted = 0
            deadline = time.monotonic() - 2 * self.heartbeat
            dead = [cid for cid, beat in self._last_beat.items()
                    if beat < deadline]
            for cid in dead:
                logger.warning("consumer %s missed heartbeats; requeueing",
                               cid[:8])
                writer = self._clients.get(cid)
                if writer is not None:
                    writer.close()
                self._drop_client(cid)
            if dead:
                for queue in list(self._consumers):
                    self._deliver(queue)


class BrokerClient:
    """Communicator-compatible client for the broker (kiwiPy role).

    Runs its protocol on the caller's event loop; heartbeats are sent from
    a background task so a busy worker still responds (kiwiPy runs a
    separate thread for the same reason — see paper §III.C.a)."""

    def __init__(self, host: str, port: int):
        self.host = host
        self.port = port
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._rpc_handlers: dict[str, Callable] = {}
        self._task_handlers: dict[str, Callable[[dict], Awaitable]] = {}
        self._broadcast_handlers: dict[int, tuple[str | None, Callable]] = {}
        self._bc_counter = itertools.count()
        self._rpc_waiters: dict[str, asyncio.Future] = {}
        self._tasks: list[asyncio.Task] = []
        self.heartbeat = 1.0

    async def connect(self) -> None:
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        # re-register any existing subscriptions (reconnect path)
        for identifier in self._rpc_handlers:
            self._send({"kind": "rpc_register", "identifier": identifier})
        for queue in self._task_handlers:
            self._send({"kind": "consume", "queue": queue})
        if not self._tasks:
            self._tasks.append(asyncio.ensure_future(self._recv_loop()))
            self._tasks.append(asyncio.ensure_future(self._heartbeat_loop()))

    def _send(self, msg: dict) -> bool:
        """Best-effort write; False when the connection is down (the
        reconnect loop will recover subscriptions, but a caller awaiting
        a reply must fail fast rather than wait on a message never sent)."""
        if self._writer is None or self._writer.is_closing():
            return False
        try:
            self._writer.write(json.dumps(msg).encode() + b"\n")
            return True
        except Exception:  # noqa: BLE001 — reconnect loop will recover
            return False

    async def _heartbeat_loop(self) -> None:
        while True:
            self._send({"kind": "heartbeat"})
            await asyncio.sleep(self.heartbeat)

    async def _reconnect(self) -> None:
        delay = 0.2
        while True:
            try:
                await self.connect()
                logger.info("broker client reconnected")
                return
            except OSError:
                await asyncio.sleep(delay)
                delay = min(delay * 2, 5.0)

    async def _recv_loop(self) -> None:
        while True:
            assert self._reader is not None
            line = await self._reader.readline()
            if not line:
                # connection lost (e.g. broker reaped us while busy, or
                # broker restarted): reconnect and resubscribe. In-flight
                # RPC replies died with the connection — fail their
                # waiters instead of leaving callers awaiting forever.
                if self._writer is not None:
                    self._writer.close()
                self._reader = self._writer = None
                waiters, self._rpc_waiters = self._rpc_waiters, {}
                for fut in waiters.values():
                    if not fut.done():
                        fut.set_exception(
                            ConnectionError("broker connection lost"))
                await self._reconnect()
                continue
            msg = json.loads(line)
            kind = msg.get("kind")
            if kind == "task":
                asyncio.ensure_future(self._run_task(msg))
            elif kind == "rpc_request":
                await self._run_rpc(msg)
            elif kind == "rpc_reply":
                fut = self._rpc_waiters.pop(msg["rid"], None)
                if fut and not fut.done():
                    if "error" in msg:
                        fut.set_exception(KeyError(msg["error"]))
                    else:
                        fut.set_result(msg.get("result"))
            elif kind == "broadcast":
                import fnmatch
                _metrics.get_registry().counter(
                    "broker.broadcasts_received").inc()
                for filt, handler in list(self._broadcast_handlers.values()):
                    if filt and not fnmatch.fnmatch(msg["subject"], filt):
                        continue
                    try:
                        handler(msg["subject"], msg.get("sender"),
                                msg.get("body", {}))
                    except Exception:  # noqa: BLE001
                        logger.exception("broadcast handler failed")

    async def _run_task(self, msg: dict) -> None:
        handler = self._task_handlers.get(msg["queue"])
        if handler is None:
            self._send({"kind": "nack", "task_id": msg["task_id"],
                        "queue": msg["queue"]})
            return
        try:
            await handler(msg["payload"])
            self._send({"kind": "ack", "task_id": msg["task_id"]})
        except Exception:  # noqa: BLE001
            logger.exception("task failed; nacking for requeue")
            self._send({"kind": "nack", "task_id": msg["task_id"],
                        "queue": msg["queue"]})

    async def _run_rpc(self, msg: dict) -> None:
        handler = self._rpc_handlers.get(msg["identifier"])
        reply: dict = {"kind": "rpc_reply", "rid": msg["rid"]}
        if handler is None:
            reply["error"] = f"no handler {msg['identifier']!r}"
        else:
            try:
                res = handler(msg["msg"])
                if asyncio.iscoroutine(res):
                    res = await res
                reply["result"] = res
            except Exception as exc:  # noqa: BLE001
                reply["error"] = repr(exc)
        self._send(reply)

    # -- Communicator interface ---------------------------------------------------
    def add_rpc_subscriber(self, identifier: str, handler: Callable) -> None:
        self._rpc_handlers[identifier] = handler
        self._send({"kind": "rpc_register", "identifier": identifier})

    def remove_rpc_subscriber(self, identifier: str) -> None:
        self._rpc_handlers.pop(identifier, None)
        self._send({"kind": "rpc_unregister", "identifier": identifier})

    async def rpc_lookup(self, pattern: str = "*") -> list[str]:
        """Query the broker's live RPC-identifier directory."""
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        if not self._send({"kind": "rpc_lookup", "rid": rid,
                           "pattern": pattern}):
            self._rpc_waiters.pop(rid, None)
            raise ConnectionError("broker connection lost")
        return await fut

    async def rpc_send_async(self, identifier: str, msg: dict) -> Any:
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        t0 = time.perf_counter()
        with trace.span("broker.rpc", identifier=identifier):
            if not self._send({"kind": "rpc_send", "rid": rid,
                               "identifier": identifier, "msg": msg}):
                self._rpc_waiters.pop(rid, None)
                raise ConnectionError("broker connection lost")
            result = await fut
        _metrics.get_registry().histogram("broker.rpc_seconds").observe(
            time.perf_counter() - t0)
        return result

    def rpc_send(self, identifier: str, msg: dict) -> Any:
        return self.rpc_send_async(identifier, msg)

    def add_broadcast_subscriber(self, handler: Callable,
                                 subject_filter: str | None = None) -> int:
        token = next(self._bc_counter)
        self._broadcast_handlers[token] = (subject_filter, handler)
        return token

    def remove_broadcast_subscriber(self, token: int) -> None:
        self._broadcast_handlers.pop(token, None)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        _metrics.get_registry().counter("broker.broadcasts_sent").inc()
        self._send({"kind": "broadcast", "subject": subject,
                    "sender": sender, "body": body or {}})

    def task_send(self, queue: str, payload: dict) -> None:
        self._send({"kind": "task_send", "queue": queue, "payload": payload})

    def add_task_subscriber(self, queue: str,
                            handler: Callable[[dict], Awaitable]) -> None:
        self._task_handlers[queue] = handler
        self._send({"kind": "consume", "queue": queue})

    def close(self) -> None:
        for t in self._tasks:
            t.cancel()
        if self._writer is not None:
            self._writer.close()


class SyncBrokerClient:
    """Blocking broker client for non-async callers (the CLI, tests).

    Speaks the same newline-JSON protocol as :class:`BrokerClient` but over
    a plain socket, sending heartbeats while idle so the broker's reaper
    does not presume it dead during a long ``watch``."""

    def __init__(self, host: str, port: int, connect_timeout: float = 10.0):
        self.host = host
        self.port = port
        self.connect_timeout = connect_timeout
        self._sock: socket.socket | None = None
        self._buf = b""
        self._last_beat = 0.0
        # broadcasts that arrived interleaved with an RPC reply; a later
        # events() call must still see them
        self._pending: list[dict] = []
        self._connect()

    def _connect(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout)
        self._sock.settimeout(0.25)
        self._buf = b""
        self._last_beat = 0.0

    def _send(self, msg: dict) -> None:
        try:
            self._sock.sendall(json.dumps(msg).encode() + b"\n")
        except OSError as exc:
            raise ConnectionError("broker connection lost") from exc

    def _heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_beat >= 0.5:
            self._send({"kind": "heartbeat"})
            self._last_beat = now

    def _recv(self, deadline: float | None) -> dict | None:
        """Next message, or None once the deadline passes."""
        while True:
            # heartbeat even while draining buffered lines (e.g. a long
            # replay): the broker's reaper must keep seeing us alive
            self._heartbeat()
            if b"\n" in self._buf:
                line, self._buf = self._buf.split(b"\n", 1)
                if line.strip():
                    return json.loads(line)
                continue
            if deadline is not None and time.monotonic() > deadline:
                return None
            try:
                chunk = self._sock.recv(65536)
            except TimeoutError:
                continue
            except OSError as exc:
                raise ConnectionError("broker connection lost") from exc
            if not chunk:
                raise ConnectionError("broker closed the connection")
            self._buf += chunk

    def _await_reply(self, rid: str, timeout: float) -> Any:
        deadline = time.monotonic() + timeout
        while True:
            msg = self._recv(deadline)
            if msg is None:
                raise TimeoutError(f"no broker reply within {timeout}s")
            if msg.get("kind") == "rpc_reply" and msg.get("rid") == rid:
                if "error" in msg:
                    raise KeyError(msg["error"])
                return msg.get("result")
            if msg.get("kind") == "broadcast":
                # e.g. the state change a control intent provoked landing
                # before its rpc_reply — keep it for the next events() call
                self._pending.append(msg)

    def _request(self, build_msg, timeout: float) -> Any:
        """Send a request and await its reply; if the broker reaped this
        client while it sat idle between calls (2 missed heartbeats),
        reconnect once and retry — control intents are idempotent."""
        for attempt in (0, 1):
            rid = str(uuid.uuid4())
            try:
                self._send(build_msg(rid))
                return self._await_reply(rid, timeout)
            except ConnectionError:
                if attempt:
                    raise
                self._connect()

    def rpc(self, identifier: str, msg: dict, timeout: float = 10.0) -> Any:
        return self._request(
            lambda rid: {"kind": "rpc_send", "rid": rid,
                         "identifier": identifier, "msg": msg}, timeout)

    def lookup(self, pattern: str = "*", timeout: float = 10.0) -> list[str]:
        return self._request(
            lambda rid: {"kind": "rpc_lookup", "rid": rid,
                         "pattern": pattern}, timeout)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        self._send({"kind": "broadcast", "subject": subject,
                    "sender": sender, "body": body or {}})

    def events(self, subject_filter: str | None = None,
               timeout: float | None = None,
               replay_since: int | None = None
               ) -> Iterator[tuple[str, Any, dict]]:
        """Yield ``(subject, sender, body)`` broadcasts as they arrive;
        stops after ``timeout`` seconds of total watching (None = forever).
        ``replay_since`` first replays logged events with seq > the given
        value (0 = everything the broker still remembers)."""
        if replay_since is not None:
            self._send({"kind": "events_since", "seq": replay_since,
                        "pattern": subject_filter})
        deadline = None if timeout is None else time.monotonic() + timeout
        # replay + live can overlap around the events_since request; the
        # broker stamps every event with a unique seq — dedup on it, but
        # only until the replay catches up (keeps `seen` bounded on
        # long-lived watches)
        seen: set[int] = set()
        replaying = replay_since is not None
        while True:
            if self._pending:
                msg = self._pending.pop(0)
            else:
                msg = self._recv(deadline)
            if msg is None:
                return
            if msg.get("kind") == "events_caught_up":
                replaying = False
                seen.clear()
                continue
            if msg.get("kind") != "broadcast":
                continue
            seq = msg.get("seq")
            if replaying and seq is not None:
                if seq in seen:
                    continue
                seen.add(seq)
            subject = msg["subject"]
            if subject_filter and not fnmatch.fnmatch(subject,
                                                      subject_filter):
                continue
            yield subject, msg.get("sender"), msg.get("body", {})

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
