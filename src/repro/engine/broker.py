"""Durable cross-process message broker (the RabbitMQ role, paper §III.C).

A small asyncio TCP server backed by sqlite gives the three messaging
patterns with RabbitMQ-faithful guarantees:

* **task queues** — persistent messages (survive broker restarts), explicit
  acks, per-consumer heartbeats: a consumer that misses ``2 × heartbeat``
  is presumed dead and its un-acked tasks are requeued (paper: "upon
  missing two consecutive responses, RabbitMQ assumes the worker to be
  dead and triggers the rescheduling mechanism"). Consumers declare a
  **prefetch** (ready-queue high-water mark): excess tasks park in the
  durable queue, and delivery round-robins across distinct submitter ids
  so a bulk submitter cannot starve a trickle one.
* **RPC** — request/response routed by subscriber identifier, forwarded
  across OS processes. Process control is *multiplexed*: a worker claims
  the pks it runs with one ``own`` message instead of registering one
  identifier per process, so the broker directory stays O(workers) while
  ``rpc_send("process.<pk>")`` / ``rpc_lookup`` keep working unchanged.
  ``rpc_send`` takes an optional deadline the broker enforces with a
  ``cancelled`` reply plus a cancel notice to the (possibly hung) target.
* **broadcast** — subject-filtered fan-out: clients push their fnmatch
  patterns down with ``subscribe``/``unsubscribe`` and only matching
  events are sent (bursts are coalesced into one framed multi-event
  message). Every event is also appended to a sqlite log with a monotonic
  sequence number for replay (``events_since``); when the log exceeds its
  cap, compaction drops *superseded* state-change events of terminal
  processes first, so a terminal notification is never evicted while
  older chatter survives.

Protocol: newline-delimited JSON over TCP (loopback). This is deliberately
boring; the durability lives in sqlite (WAL), the liveness in heartbeats.
Submission paths batch: ``task_send_many`` enqueues many payloads in one
frame + one commit, and clients coalesce many frames per syscall.
"""

from __future__ import annotations

import asyncio
import fnmatch
import itertools
import json
import logging
import os
import re
import socket
import sqlite3
import time
import uuid
from typing import Any, Awaitable, Callable, Iterator

from repro.chaos import faults as chaos
from repro.core.statemachine import TERMINAL_STATES
from repro.observability import metrics as _metrics
from repro.observability import trace

logger = logging.getLogger("repro.engine.broker")

_TASKS_SCHEMA = """
CREATE TABLE IF NOT EXISTS tasks (
    id INTEGER PRIMARY KEY AUTOINCREMENT,
    queue TEXT NOT NULL,
    payload TEXT NOT NULL,
    state TEXT NOT NULL DEFAULT 'ready',   -- ready | inflight
    consumer TEXT,
    delivered_at REAL,
    created_at REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_tasks_queue ON tasks(queue, state);
CREATE TABLE IF NOT EXISTS events (
    seq INTEGER PRIMARY KEY AUTOINCREMENT,
    subject TEXT NOT NULL,
    sender TEXT,
    body TEXT NOT NULL,
    ts REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS leases (
    pk INTEGER PRIMARY KEY,
    worker TEXT,                           -- NULL = expired, awaiting regrant
    epoch INTEGER NOT NULL DEFAULT 1,
    renewed_at REAL NOT NULL
);
"""

#: keep at most this many events in the durable broadcast log
EVENT_LOG_CAP = 10000

_TERMINAL = tuple(s.value for s in TERMINAL_STATES)
_PROCESS_ID = re.compile(r"^process\.(\d+)$")
_STATE_SUBJECT = re.compile(r"^state_changed\.(\d+)\.([a-z_]+)$")


def _encode(msg: dict) -> bytes:
    return json.dumps(msg).encode() + b"\n"


class BrokerServer:
    """The broker daemon. One per deployment (like one RabbitMQ service)."""

    def __init__(self, db_path: str, host: str = "127.0.0.1", port: int = 0,
                 heartbeat: float = 5.0, event_log_cap: int = EVENT_LOG_CAP):
        self.db_path = db_path
        self.host = host
        self.port = port
        self.heartbeat = heartbeat
        self.event_log_cap = event_log_cap
        self._server: asyncio.AbstractServer | None = None
        self._clients: dict[str, asyncio.StreamWriter] = {}
        self._consumers: dict[str, set[str]] = {}      # queue -> client ids
        self._rpc: dict[str, str] = {}                 # identifier -> client id
        self._owners: dict[int, str] = {}              # pk -> owning client id
        self._names: dict[str, str] = {}               # client id -> worker name
        # pk -> [worker name | None, epoch]; mirrors the durable `leases`
        # table. Lease identity is the stable worker *name* (not the
        # per-connection client id), so a reconnect does not look like a
        # new owner — the epoch only bumps when a pk is granted to a
        # *different* worker (the fencing event).
        self._leases: dict[int, list] = {}
        self._subs: dict[str, set[str]] = {}           # client id -> patterns
        self._prefetch: dict[str, int] = {}            # client id -> HWM
        self._last_beat: dict[str, float] = {}
        self._pending_rpc: dict[str, tuple[str, Any]] = {}
        self._rpc_timers: dict[str, asyncio.TimerHandle] = {}
        self._bc_outbox: list[dict] = []
        self._bc_scheduled = False
        self._deliver_pending: set[str] = set()
        self._deliver_scheduled = False
        self._rr: dict[str, int] = {}                  # queue -> fair cursor
        self._events_uncommitted = 0
        self._dirty = 0
        self._conn = None
        self._reaper_task: asyncio.Task | None = None
        #: control-plane traffic accounting, served by ``broker_stats``
        self.stats = {
            "messages_in": 0, "messages_out": 0, "tasks_enqueued": 0,
            "tasks_delivered": 0, "events_logged": 0, "events_compacted": 0,
            "rpc_cancelled": 0, "heartbeats": 0, "clients_dropped": 0,
            # fenced-ownership accounting: expired leases (epoch fence
            # armed) and refused stale re-claims from woken zombies
            "leases_granted": 0, "leases_expired": 0, "stale_claims": 0,
            # chaos-injected frame mutations (duplicate delivery /
            # dropped broadcasts) — the harness asserts these actually
            # fired instead of trusting the scenario spec
            "chaos_duplicated": 0, "chaos_dropped": 0,
        }

    # -- storage ------------------------------------------------------------
    def conn(self) -> sqlite3.Connection:
        if self._conn is None:
            os.makedirs(os.path.dirname(os.path.abspath(self.db_path)),
                        exist_ok=True)
            self._conn = sqlite3.connect(self.db_path)
            self._conn.row_factory = sqlite3.Row
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.executescript(_TASKS_SCHEMA)
            cols = [r[1] for r in self._conn.execute(
                "PRAGMA table_info(tasks)")]
            if "submitter" not in cols:
                self._conn.execute("ALTER TABLE tasks ADD COLUMN submitter "
                                   "TEXT NOT NULL DEFAULT ''")
            self._conn.execute(
                "CREATE INDEX IF NOT EXISTS idx_tasks_fair ON "
                "tasks(queue, state, submitter)")
            self._conn.execute(
                "CREATE INDEX IF NOT EXISTS idx_events_ts ON events(ts)")
            self._conn.commit()
        return self._conn

    def _maybe_commit(self, n: int = 1) -> None:
        """Batch task-table commits: at-least-once delivery means losing an
        uncommitted state flip only causes a redelivery, never a loss."""
        self._dirty += n
        if self._dirty >= 200:
            chaos.fault_point("broker.commit.pre")
            self.conn().commit()
            self._dirty = 0

    def _commit_now(self) -> None:
        if self._dirty or self._events_uncommitted:
            chaos.fault_point("broker.commit.pre")
            self.conn().commit()
            self._dirty = 0
            self._events_uncommitted = 0

    # -- lifecycle -----------------------------------------------------------
    def _recover(self) -> None:
        """Rebuild in-memory state from sqlite after a (re)start. Tasks a
        dead broker had marked inflight are requeued — their consumers'
        connections died with the old process, so at-least-once semantics
        demand redelivery. Leases survive verbatim (same worker name ⇒ no
        epoch bump when its task is redelivered to it) with a fresh
        renewal stamp so the reaper gives reconnecting workers a full
        grace window before expiring anything."""
        conn = self.conn()
        requeued = conn.execute(
            "UPDATE tasks SET state='ready', consumer=NULL"
            " WHERE state='inflight'").rowcount
        now = time.time()
        conn.execute("UPDATE leases SET renewed_at=?", (now,))
        conn.commit()
        self._leases = {
            row["pk"]: [row["worker"], row["epoch"]]
            for row in conn.execute("SELECT pk, worker, epoch FROM leases")}
        if requeued or self._leases:
            logger.info("broker recovery: requeued %d inflight task(s), "
                        "%d lease(s) loaded", requeued, len(self._leases))

    async def start(self) -> tuple[str, int]:
        self._recover()
        self._server = await asyncio.start_server(self._on_client, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._reaper_task = asyncio.ensure_future(self._reaper())
        logger.info("broker listening on %s:%d", self.host, self.port)
        return self.host, self.port

    async def stop(self) -> None:
        if self._reaper_task is not None:
            self._reaper_task.cancel()
            self._reaper_task = None
        for timer in self._rpc_timers.values():
            timer.cancel()
        self._rpc_timers.clear()
        # closing the writers EOFs each _on_client loop so the per-client
        # handler tasks finish instead of lingering past the server
        for writer in list(self._clients.values()):
            writer.close()
        self._clients.clear()
        self._last_beat.clear()
        if self._conn is not None:
            self._commit_now()
        if self._server:
            self._server.close()
            await self._server.wait_closed()
        await asyncio.sleep(0)  # let client tasks observe the EOF
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    # -- client handling ---------------------------------------------------------
    async def _on_client(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        cid = str(uuid.uuid4())
        self._clients[cid] = writer
        self._last_beat[cid] = time.monotonic()
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except json.JSONDecodeError:
                    continue
                await self._handle(cid, msg)
        except (ConnectionResetError, asyncio.IncompleteReadError):
            pass
        finally:
            self._drop_client(cid)

    def _drop_client(self, cid: str) -> None:
        """Full disconnect cleanup, run the moment a client's connection
        dies (EOF/reset — a SIGKILLed worker's sockets close immediately)
        or its heartbeats lapse. Auto-disowns every pk the client claimed
        and fails every RPC routed to or awaited by it, so
        ``rpc_lookup``/``rpc_send`` never route to a dead worker in the
        window between its crash and the tasks' redelivery. Idempotent —
        the reaper and the connection handler can both call it."""
        had_conn = self._clients.pop(cid, None) is not None
        had_beat = self._last_beat.pop(cid, None) is not None
        if had_conn or had_beat:
            self.stats["clients_dropped"] += 1
        self._subs.pop(cid, None)
        self._prefetch.pop(cid, None)
        for consumers in self._consumers.values():
            consumers.discard(cid)
        for ident in [k for k, v in self._rpc.items() if v == cid]:
            del self._rpc[ident]
        # auto-disown: a dead worker's pks leave the directory at once,
        # so `process.<pk>` stops resolving until a new worker owns it
        for pk in [p for p, v in self._owners.items() if v == cid]:
            del self._owners[pk]
        # expire the dead worker's leases (unless the same worker *name*
        # is still connected under another client id — a reconnect is not
        # a death). The epoch is NOT bumped here: the fence arms only
        # when the pk is re-granted to a different worker, so a worker
        # that merely reconnects keeps writing under its old epoch.
        name = self._names.pop(cid, None)
        if name is not None and name not in self._names.values():
            for pk, lease in self._leases.items():
                if lease[0] == name:
                    chaos.fault_point("lease.expire", pk=pk)
                    lease[0] = None
                    self.conn().execute(
                        "UPDATE leases SET worker=NULL, renewed_at=?"
                        " WHERE pk=?", (time.time(), pk))
                    self.stats["leases_expired"] += 1
        # fail RPCs whose target just died — callers must not hang forever
        for rid in [r for r, (_, target) in self._pending_rpc.items()
                    if target == cid]:
            origin, _ = self._pending_rpc.pop(rid)
            timer = self._rpc_timers.pop(rid, None)
            if timer is not None:
                timer.cancel()
            self._send(origin, {"kind": "rpc_reply", "rid": rid,
                                "error": "rpc target disconnected"})
        # ...and discard replies queued FOR the dead client: nobody is
        # listening, and a lingering timer would fire into the void
        for rid in [r for r, (origin, _) in self._pending_rpc.items()
                    if origin == cid]:
            self._pending_rpc.pop(rid)
            timer = self._rpc_timers.pop(rid, None)
            if timer is not None:
                timer.cancel()
        # requeue this consumer's inflight tasks immediately...
        self.conn().execute(
            "UPDATE tasks SET state='ready', consumer=NULL WHERE "
            "state='inflight' AND consumer=?", (cid,))
        self._commit_now()
        # ...and push them to surviving/new consumers right away
        for queue in list(self._consumers):
            self._deliver(queue)

    def _send(self, cid: str, msg: dict) -> None:
        writer = self._clients.get(cid)
        if writer is None:
            return
        if writer.is_closing():
            self._drop_client(cid)
            return
        try:
            writer.write(_encode(msg))
            self.stats["messages_out"] += 1
        except Exception:  # noqa: BLE001
            self._drop_client(cid)

    # -- message dispatch ------------------------------------------------------------
    async def _handle(self, cid: str, msg: dict) -> None:
        kind = msg.get("kind")
        self.stats["messages_in"] += 1
        if kind == "heartbeat":
            self.stats["heartbeats"] += 1
            self._last_beat[cid] = time.monotonic()
        elif kind == "task_send":
            self._enqueue_tasks(msg["queue"], [msg["payload"]],
                                msg.get("submitter"))
            if msg.get("rid"):
                # submitter asked for a delivery ack: make the row durable
                # before confirming (replaces the old fire-and-sleep path)
                self._commit_now()
                self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                                 "result": 1})
            self._schedule_deliver(msg["queue"])
        elif kind == "task_send_many":
            payloads = msg.get("payloads", [])
            self._enqueue_tasks(msg["queue"], payloads, msg.get("submitter"))
            if msg.get("rid"):
                self._commit_now()
                self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                                 "result": len(payloads)})
            self._schedule_deliver(msg["queue"])
        elif kind == "consume":
            self._consumers.setdefault(msg["queue"], set()).add(cid)
            self._prefetch[cid] = max(1, int(msg.get("prefetch", 1)))
            self._deliver(msg["queue"])
        elif kind == "ack":
            # consumer guard: only the client a task is inflight to may
            # settle it — a woken zombie's stale ack must not delete a
            # row that was requeued (and possibly redelivered) while it
            # was unresponsive
            self.conn().execute(
                "DELETE FROM tasks WHERE id=? AND state='inflight'"
                " AND consumer=?", (msg["task_id"], cid))
            self._maybe_commit()
            # deliver further work to this consumer
            for queue, members in self._consumers.items():
                if cid in members:
                    self._schedule_deliver(queue)
        elif kind == "nack":
            self.conn().execute(
                "UPDATE tasks SET state='ready', consumer=NULL WHERE id=?"
                " AND state='inflight' AND consumer=?",
                (msg["task_id"], cid))
            self._maybe_commit()
            self._schedule_deliver(msg["queue"])
        elif kind == "rpc_register":
            self._rpc[msg["identifier"]] = cid
        elif kind == "rpc_unregister":
            if self._rpc.get(msg["identifier"]) == cid:
                del self._rpc[msg["identifier"]]
        elif kind == "hello":
            # a worker announces its stable name; lease identity hangs
            # off this, not the per-connection client id
            self._names[cid] = str(msg.get("worker", cid))
        elif kind == "own":
            # multiplexed process control: one frame claims many pks; the
            # directory stays O(workers) instead of O(live processes).
            # Claims carry the epoch the worker believes it holds — a
            # claim older than the lease table's epoch is a zombie
            # re-asserting ownership it already lost, and is refused.
            epochs = msg.get("epochs") or {}
            refused: list[int] = []
            for pk in msg.get("pks", []):
                pk = int(pk)
                lease = self._leases.get(pk)
                claimed = epochs.get(str(pk))
                if (lease is not None and claimed is not None
                        and int(claimed) < lease[1]):
                    self.stats["stale_claims"] += 1
                    refused.append(pk)
                    continue
                self._owners[pk] = cid
                if lease is not None and lease[0] is None:
                    # expired lease re-claimed by its last valid holder
                    # (same epoch): restore without bumping the fence
                    name = self._names.get(cid)
                    if name is not None:
                        lease[0] = name
                        self.conn().execute(
                            "UPDATE leases SET worker=?, renewed_at=?"
                            " WHERE pk=?", (name, time.time(), pk))
                        self._maybe_commit()
            if refused:
                logger.warning("refused stale ownership claim for pks %s",
                               refused)
                self._send(cid, {"kind": "own_refused", "pks": refused})
        elif kind == "disown":
            for pk in msg.get("pks", []):
                pk = int(pk)
                if self._owners.get(pk) == cid:
                    del self._owners[pk]
                # the process reached a terminal state under this worker:
                # its lease is spent — drop the row so the table tracks
                # only live ownership
                lease = self._leases.get(pk)
                if lease is not None and lease[0] == self._names.get(cid):
                    del self._leases[pk]
                    self.conn().execute("DELETE FROM leases WHERE pk=?",
                                        (pk,))
                    self._maybe_commit()
        elif kind == "subscribe":
            self._subs.setdefault(cid, set()).update(
                msg.get("patterns", []))
        elif kind == "unsubscribe":
            patterns = msg.get("patterns")
            if patterns is None:
                self._subs.pop(cid, None)
            else:
                subs = self._subs.get(cid)
                if subs is not None:
                    subs.difference_update(patterns)
                    if not subs:
                        self._subs.pop(cid, None)
        elif kind == "sub_sync":
            # barrier: replying proves every earlier frame on this
            # connection (e.g. a subscribe) has been processed
            self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                             "result": True})
        elif kind == "rpc_lookup":
            # the live-identifier directory: how clients discover which
            # processes/workers are reachable right now. Owned pks are
            # synthesized back into per-pk identifiers for compatibility.
            pattern = msg.get("pattern", "*")
            idents = set(self._rpc)
            idents.update(f"process.{pk}" for pk in self._owners)
            self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                             "result": sorted(
                                 i for i in idents
                                 if fnmatch.fnmatch(i, pattern))})
        elif kind == "rpc_send":
            target = self._rpc.get(msg["identifier"])
            if target is None:
                m = _PROCESS_ID.match(msg["identifier"])
                if m is not None:
                    target = self._owners.get(int(m.group(1)))
            if target is None:
                self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                                 "error": f"no subscriber "
                                          f"{msg['identifier']!r}"})
            else:
                rid = msg["rid"]
                self._pending_rpc[rid] = (cid, target)
                timeout = msg.get("timeout")
                if timeout is not None:
                    self._rpc_timers[rid] = (
                        asyncio.get_running_loop().call_later(
                            float(timeout), self._cancel_rpc, rid))
                self._send(target, {"kind": "rpc_request", "rid": rid,
                                    "identifier": msg["identifier"],
                                    "msg": msg["msg"]})
        elif kind == "rpc_reply":
            timer = self._rpc_timers.pop(msg["rid"], None)
            if timer is not None:
                timer.cancel()
            origin = self._pending_rpc.pop(msg["rid"], None)
            if origin is not None:
                self._send(origin[0], msg)
        elif kind == "broadcast":
            seq = self._log_event(msg)
            self._bc_outbox.append({"seq": seq, "subject": msg["subject"],
                                    "sender": msg.get("sender"),
                                    "body": msg.get("body", {})})
            if not self._bc_scheduled:
                self._bc_scheduled = True
                asyncio.get_running_loop().call_soon(self._flush_broadcasts)
        elif kind == "broker_stats":
            queues: dict[str, dict] = {}
            for row in self.conn().execute(
                    "SELECT queue, state, COUNT(*) c FROM tasks"
                    " GROUP BY queue, state"):
                queues.setdefault(row["queue"], {})[row["state"]] = row["c"]
            n_events = self.conn().execute(
                "SELECT COUNT(*) c FROM events").fetchone()["c"]
            self._send(cid, {"kind": "rpc_reply", "rid": msg["rid"],
                             "result": {**self.stats,
                                        "clients": len(self._clients),
                                        "owned_pks": len(self._owners),
                                        "rpc_identifiers": len(self._rpc),
                                        "leases": len(self._leases),
                                        "event_log_size": n_events,
                                        "queues": queues}})
        elif kind == "events_since":
            # durable replay: stream the logged events this client missed
            pattern = msg.get("pattern")
            rows = self.conn().execute(
                "SELECT seq, subject, sender, body FROM events WHERE seq>?"
                " ORDER BY seq", (msg.get("seq", 0),)).fetchall()
            last = msg.get("seq", 0)
            for row in rows:
                last = row["seq"]
                if pattern and not fnmatch.fnmatch(row["subject"], pattern):
                    continue
                self._send(cid, {"kind": "broadcast", "seq": row["seq"],
                                 "subject": row["subject"],
                                 "sender": json.loads(row["sender"] or "null"),
                                 "body": json.loads(row["body"]),
                                 "replay": True})
            self._send(cid, {"kind": "events_caught_up", "seq": last})

    def _cancel_rpc(self, rid: str) -> None:
        """Deadline enforcement: tell the caller the RPC is cancelled and
        the (possibly hung) target to abandon the handler."""
        self._rpc_timers.pop(rid, None)
        entry = self._pending_rpc.pop(rid, None)
        if entry is None:
            return
        origin, target = entry
        self.stats["rpc_cancelled"] += 1
        self._send(origin, {"kind": "rpc_reply", "rid": rid,
                            "cancelled": True,
                            "error": "cancelled: rpc deadline exceeded"})
        self._send(target, {"kind": "rpc_cancel", "rid": rid})

    # -- task ingest -------------------------------------------------------------
    def _enqueue_tasks(self, queue: str, payloads: list,
                       submitter: str | None) -> None:
        now = time.time()
        rows = []
        for payload in payloads:
            sub = submitter
            if sub is None and isinstance(payload, dict):
                sub = payload.get("submitter")
            rows.append((queue, json.dumps(payload), sub or "", now))
        self.conn().executemany(
            "INSERT INTO tasks (queue, payload, submitter, created_at)"
            " VALUES (?,?,?,?)", rows)
        self.stats["tasks_enqueued"] += len(rows)
        self._maybe_commit(len(rows))

    # -- broadcast fan-out -------------------------------------------------------
    def _flush_broadcasts(self) -> None:
        """Coalesced, subject-filtered fan-out: a burst of broadcasts that
        arrived in one scheduling tick goes to each interested client as a
        single ``broadcast_batch`` frame; clients without a matching
        subscription get nothing at all."""
        self._bc_scheduled = False
        events, self._bc_outbox = self._bc_outbox, []
        if not events:
            return
        for cid, patterns in list(self._subs.items()):
            if cid not in self._clients:
                continue
            matched = [ev for ev in events
                       if any(fnmatch.fnmatch(ev["subject"], p)
                              for p in patterns)]
            if not matched:
                continue
            # chaos: a partition between broker and this client — the
            # frames vanish, the durable event log keeps them for replay,
            # and waiters must fall back to their liveness re-check
            if chaos.fault_point("broker.broadcast.pre") == "drop":
                self.stats["chaos_dropped"] += len(matched)
                continue
            if len(matched) == 1:
                self._send(cid, {"kind": "broadcast", **matched[0]})
            else:
                self._send(cid, {"kind": "broadcast_batch",
                                 "events": matched})

    def _log_event(self, msg: dict) -> int:
        """Append a broadcast to the durable event log; returns its seq.
        Commits are batched (every 50 events + the reaper tick): replay
        reads go through the same connection and therefore see uncommitted
        rows, so fan-out latency never waits on fsync."""
        conn = self.conn()
        cur = conn.execute(
            "INSERT INTO events (subject, sender, body, ts) VALUES (?,?,?,?)",
            (msg["subject"], json.dumps(msg.get("sender")),
             json.dumps(msg.get("body", {})), time.time()))
        seq = cur.lastrowid
        self.stats["events_logged"] += 1
        every = max(1, min(1000, self.event_log_cap // 4))
        if seq % every == 0:
            self._compact_events()
        self._events_uncommitted += 1
        if self._events_uncommitted >= 50:
            conn.commit()
            self._events_uncommitted = 0
        return seq

    def _compact_events(self) -> None:
        """Shrink the event log to its cap, *least-valuable first*:

        1. superseded ``state_changed`` events of pks that already have a
           later terminal event (a replaying waiter only needs the
           terminal one),
        2. oldest remaining non-terminal events,
        3. only then — still over cap — oldest terminal notifications.
        """
        conn = self.conn()
        excess = (conn.execute("SELECT COUNT(*) c FROM events").fetchone()
                  ["c"]) - self.event_log_cap
        if excess <= 0:
            return
        rows = conn.execute(
            "SELECT seq, subject FROM events ORDER BY seq").fetchall()
        latest: dict[int, tuple[int, str]] = {}
        for row in rows:
            m = _STATE_SUBJECT.match(row["subject"])
            if m is not None:
                latest[int(m.group(1))] = (row["seq"], m.group(2))
        terminal_seqs = {seq for seq, state in latest.values()
                         if state in _TERMINAL}
        doomed: list[int] = []
        superseded_of_terminal = []
        other_non_terminal = []
        for row in rows:
            m = _STATE_SUBJECT.match(row["subject"])
            if row["seq"] in terminal_seqs:
                continue
            pk = int(m.group(1)) if m is not None else None
            if pk is not None and latest[pk][0] in terminal_seqs:
                superseded_of_terminal.append(row["seq"])
            else:
                other_non_terminal.append(row["seq"])
        for pool in (superseded_of_terminal, other_non_terminal,
                     sorted(terminal_seqs)):
            for seq in pool:
                if len(doomed) >= excess:
                    break
                doomed.append(seq)
            if len(doomed) >= excess:
                break
        conn.executemany("DELETE FROM events WHERE seq=?",
                         [(s,) for s in doomed])
        self.stats["events_compacted"] += len(doomed)

    # -- delivery ---------------------------------------------------------------------
    def _schedule_deliver(self, queue: str) -> None:
        """Debounce: a burst of sends/acks in one tick triggers a single
        delivery round per queue instead of one O(capacity) pass each."""
        self._deliver_pending.add(queue)
        if self._deliver_scheduled:
            return
        self._deliver_scheduled = True
        try:
            asyncio.get_running_loop().call_soon(self._flush_deliveries)
        except RuntimeError:
            self._deliver_scheduled = False
            self._flush_deliveries()

    def _flush_deliveries(self) -> None:
        self._deliver_scheduled = False
        pending, self._deliver_pending = self._deliver_pending, set()
        for queue in pending:
            self._deliver(queue)

    def _ready_rows(self, queue: str, limit: int) -> list:
        """Up to ``limit`` ready rows, FIFO — but interleaved round-robin
        across distinct submitter ids so one bulk submitter's backlog
        cannot starve a trickle submitter (fair scheduling)."""
        conn = self.conn()
        subs = [r["s"] for r in conn.execute(
            "SELECT DISTINCT submitter s FROM tasks"
            " WHERE queue=? AND state='ready'", (queue,))]
        if len(subs) <= 1:
            return conn.execute(
                "SELECT id, payload FROM tasks WHERE queue=? AND"
                " state='ready' ORDER BY id LIMIT ?",
                (queue, limit)).fetchall()
        cursor = self._rr.get(queue, 0) % len(subs)
        self._rr[queue] = cursor + 1
        subs = subs[cursor:] + subs[:cursor]
        per_sub = [conn.execute(
            "SELECT id, payload FROM tasks WHERE queue=? AND state='ready'"
            " AND submitter=? ORDER BY id LIMIT ?",
            (queue, s, limit)).fetchall() for s in subs]
        out: list = []
        for batch in itertools.zip_longest(*per_sub):
            for row in batch:
                if row is not None:
                    out.append(row)
                    if len(out) >= limit:
                        return out
        return out

    def _grant_lease(self, pk: int, cid: str) -> int:
        """Grant (or renew) the durable ``(pk, worker, epoch)`` lease at
        delivery time; returns the epoch the delivery is fenced under.
        The epoch bumps exactly when the pk moves to a *different* worker
        than the lease's holder — that bump is what lets the store refuse
        a write from the previous holder should it turn out to be a
        still-running zombie rather than a corpse."""
        name = self._names.get(cid, cid)
        lease = self._leases.get(pk)
        if lease is None:
            lease = self._leases[pk] = [name, 1]
        elif lease[0] != name:
            lease[0] = name
            lease[1] += 1
        else:
            return lease[1]
        self.conn().execute(
            "INSERT INTO leases (pk, worker, epoch, renewed_at)"
            " VALUES (?,?,?,?) ON CONFLICT(pk) DO UPDATE SET"
            " worker=excluded.worker, epoch=excluded.epoch,"
            " renewed_at=excluded.renewed_at",
            (pk, name, lease[1], time.time()))
        self.stats["leases_granted"] += 1
        self._maybe_commit()
        return lease[1]

    def _deliver(self, queue: str) -> None:
        consumers = sorted(c for c in self._consumers.get(queue, set())
                           if c in self._clients)
        if not consumers:
            return
        conn = self.conn()
        inflight = {
            r["consumer"]: r["c"] for r in conn.execute(
                "SELECT consumer, COUNT(*) c FROM tasks WHERE queue=? AND"
                " state='inflight' GROUP BY consumer", (queue,))}
        # per-consumer capacity = declared prefetch (the ready-queue
        # high-water mark) minus what it already holds; anything beyond
        # total capacity stays parked in the durable queue (backpressure)
        capacity = {c: max(0, self._prefetch.get(c, 1) - inflight.get(c, 0))
                    for c in consumers}
        total = sum(capacity.values())
        if total <= 0:
            return
        rows = self._ready_rows(queue, total)
        if not rows:
            return
        ring = itertools.cycle(consumers)
        delivered = 0
        now = time.time()
        for row in rows:
            target = None
            for _ in range(len(consumers)):
                cand = next(ring)
                if capacity.get(cand, 0) > 0:
                    target = cand
                    break
            if target is None:
                break
            capacity[target] -= 1
            conn.execute(
                "UPDATE tasks SET state='inflight', consumer=?, delivered_at=?"
                " WHERE id=?", (target, now, row["id"]))
            payload = json.loads(row["payload"])
            if isinstance(payload, dict) and "pk" in payload:
                # fenced ownership: the frame carries the lease epoch the
                # target may write the store under
                payload["epoch"] = self._grant_lease(int(payload["pk"]),
                                                     target)
            frame = {"kind": "task", "queue": queue, "task_id": row["id"],
                     "payload": payload}
            self._send(target, frame)
            # chaos: an at-least-once transport may hand the same frame
            # over twice — consumers must dedup on task_id
            if chaos.fault_point("broker.deliver.pre",
                                 queue=queue) == "duplicate":
                self._send(target, frame)
                self.stats["chaos_duplicated"] += 1
            delivered += 1
        self.stats["tasks_delivered"] += delivered
        self._maybe_commit(delivered)

    # -- liveness ----------------------------------------------------------------------
    async def _reaper(self) -> None:
        """Requeue tasks of consumers that missed two heartbeats, and keep
        the lease table honest: renew leases whose holder is still
        beating, expire leases whose holder has vanished (e.g. it was
        connected to a previous broker incarnation and never came back)."""
        while True:
            await asyncio.sleep(self.heartbeat)
            self._commit_now()
            deadline = time.monotonic() - 2 * self.heartbeat
            dead = [cid for cid, beat in self._last_beat.items()
                    if beat < deadline]
            for cid in dead:
                logger.warning("consumer %s missed heartbeats; requeueing",
                               cid[:8])
                writer = self._clients.get(cid)
                if writer is not None:
                    writer.close()
                self._drop_client(cid)
            if dead:
                for queue in list(self._consumers):
                    self._deliver(queue)
            self._sweep_leases()

    def _sweep_leases(self) -> None:
        live_names = set(self._names.values())
        now = time.time()
        renew: list[int] = []
        for pk, lease in self._leases.items():
            if lease[0] is None:
                continue
            if lease[0] in live_names:
                renew.append(pk)
            else:
                # holder is gone with no connection to observe dying —
                # after the grace window stamped at recovery, the reaper
                # is what expires it
                row = self.conn().execute(
                    "SELECT renewed_at FROM leases WHERE pk=?",
                    (pk,)).fetchone()
                if row is not None and row["renewed_at"] < (
                        now - 2 * self.heartbeat):
                    chaos.fault_point("lease.expire", pk=pk)
                    lease[0] = None
                    self.conn().execute(
                        "UPDATE leases SET worker=NULL, renewed_at=?"
                        " WHERE pk=?", (now, pk))
                    self.stats["leases_expired"] += 1
        if renew:
            self.conn().executemany(
                "UPDATE leases SET renewed_at=? WHERE pk=?",
                [(now, pk) for pk in renew])
        if renew or self._dirty:
            self._commit_now()


class BrokerClient:
    """Communicator-compatible client for the broker (kiwiPy role).

    Runs its protocol on the caller's event loop; heartbeats are sent from
    a background task so a busy worker still responds (kiwiPy runs a
    separate thread for the same reason — see paper §III.C.a).

    Writes are coalesced: frames queued in one scheduling tick leave in a
    single syscall. Process-control registrations (``process.<pk>``) are
    *not* sent as per-pk ``rpc_register`` frames — the client keeps the
    handler locally and claims the pk via a batched ``own`` message, so
    10k live processes cost the broker one directory entry, not 10k."""

    def __init__(self, host: str, port: int, worker_name: str | None = None):
        self.host = host
        self.port = port
        #: stable identity for fenced ownership; a daemon worker sets
        #: this to its `worker.<pid>-<nonce>` id so leases survive
        #: reconnects (lease identity is the name, not the connection)
        self.worker_name = worker_name
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._rpc_handlers: dict[str, Callable] = {}
        self._task_handlers: dict[str, Callable[[dict], Awaitable]] = {}
        self._task_prefetch: dict[str, int] = {}
        self._broadcast_handlers: dict[int, tuple[str | None, Callable]] = {}
        self._bc_counter = itertools.count()
        self._bc_patterns: dict[str, int] = {}        # pattern -> refcount
        self._rpc_waiters: dict[str, asyncio.Future] = {}
        self._rpc_tasks: dict[str, asyncio.Task] = {}
        self._outbox: list[bytes] = []
        self._flush_scheduled = False
        self._pending_own: set[int] = set()
        self._pending_disown: set[int] = set()
        self._pk_epochs: dict[int, int] = {}          # pk -> lease epoch
        self._active_tasks: set[int] = set()
        self._tasks: list[asyncio.Task] = []
        self.heartbeat = 1.0

    async def connect(self) -> None:
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        if self.worker_name is not None:
            # announce identity before anything else: ownership claims
            # and lease grants key off this name
            self._send({"kind": "hello", "worker": self.worker_name})
        # re-register any existing subscriptions (reconnect path)
        self._pending_disown.clear()
        for identifier in self._rpc_handlers:
            m = _PROCESS_ID.match(identifier)
            if m is not None:
                self._pending_own.add(int(m.group(1)))
            else:
                self._send({"kind": "rpc_register", "identifier": identifier})
        if self._pending_own:
            self._schedule_flush()
        for queue in self._task_handlers:
            self._send({"kind": "consume", "queue": queue,
                        "prefetch": self._task_prefetch.get(queue, 1)})
        for pattern in self._bc_patterns:
            self._send({"kind": "subscribe", "patterns": [pattern]})
        if not self._tasks:
            self._tasks.append(asyncio.ensure_future(self._recv_loop()))
            self._tasks.append(asyncio.ensure_future(self._heartbeat_loop()))

    # -- outgoing frames: write coalescing --------------------------------------
    def _send(self, msg: dict) -> bool:
        """Best-effort write; False when the connection is down (the
        reconnect loop will recover subscriptions, but a caller awaiting
        a reply must fail fast rather than wait on a message never sent).
        Frames are staged in an outbox and flushed once per scheduling
        tick — many messages per syscall."""
        if self._writer is None or self._writer.is_closing():
            return False
        self._outbox.append(_encode(msg))
        self._schedule_flush()
        return True

    def _schedule_flush(self) -> None:
        if self._flush_scheduled:
            return
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:
            self._flush_outbox()
            return
        self._flush_scheduled = True
        loop.call_soon(self._flush_outbox)

    def _flush_outbox(self) -> None:
        self._flush_scheduled = False
        frames: list[bytes] = []
        if self._pending_own:
            pks = sorted(self._pending_own)
            frames.append(_encode({
                "kind": "own", "pks": pks,
                # epoch-validated re-claim: the broker refuses claims
                # whose epoch is older than the lease table's (a zombie
                # trying to re-assert ownership it already lost)
                "epochs": {str(pk): self._pk_epochs[pk] for pk in pks
                           if pk in self._pk_epochs}}))
            self._pending_own.clear()
        if self._pending_disown:
            frames.append(_encode({"kind": "disown",
                                   "pks": sorted(self._pending_disown)}))
            self._pending_disown.clear()
        frames.extend(self._outbox)
        self._outbox = []
        if not frames:
            return
        writer = self._writer
        if writer is None or writer.is_closing():
            return
        try:
            writer.write(b"".join(frames))
        except Exception:  # noqa: BLE001 — reconnect loop will recover
            pass

    def _queue_ownership(self, pk: int, owned: bool) -> None:
        if owned:
            self._pending_own.add(pk)
            self._pending_disown.discard(pk)
        else:
            self._pending_disown.add(pk)
            self._pending_own.discard(pk)
            self._pk_epochs.pop(pk, None)
        self._schedule_flush()

    async def _heartbeat_loop(self) -> None:
        while True:
            self._send({"kind": "heartbeat"})
            await asyncio.sleep(self.heartbeat)

    async def _reconnect(self) -> None:
        delay = 0.2
        while True:
            try:
                await self.connect()
                logger.info("broker client reconnected")
                return
            except OSError:
                await asyncio.sleep(delay)
                delay = min(delay * 2, 5.0)

    async def _recv_loop(self) -> None:
        while True:
            assert self._reader is not None
            line = await self._reader.readline()
            if not line:
                # connection lost (e.g. broker reaped us while busy, or
                # broker restarted): reconnect and resubscribe. In-flight
                # RPC replies died with the connection — fail their
                # waiters instead of leaving callers awaiting forever.
                if self._writer is not None:
                    self._writer.close()
                self._reader = self._writer = None
                self._outbox.clear()
                waiters, self._rpc_waiters = self._rpc_waiters, {}
                for fut in waiters.values():
                    if not fut.done():
                        fut.set_exception(
                            ConnectionError("broker connection lost"))
                await self._reconnect()
                continue
            msg = json.loads(line)
            kind = msg.get("kind")
            if kind == "task":
                asyncio.ensure_future(self._run_task(msg))
            elif kind == "rpc_request":
                # run the handler in its own task: a hung handler must not
                # wedge this receive loop (and the broker can cancel it)
                rid = msg["rid"]
                task = asyncio.ensure_future(self._run_rpc(msg))
                self._rpc_tasks[rid] = task
                task.add_done_callback(
                    lambda _t, rid=rid: self._rpc_tasks.pop(rid, None))
            elif kind == "rpc_cancel":
                task = self._rpc_tasks.pop(msg["rid"], None)
                if task is not None:
                    task.cancel()
            elif kind == "rpc_reply":
                fut = self._rpc_waiters.pop(msg["rid"], None)
                if fut and not fut.done():
                    if msg.get("cancelled"):
                        fut.set_exception(TimeoutError(
                            msg.get("error", "rpc cancelled")))
                    elif "error" in msg:
                        fut.set_exception(KeyError(msg["error"]))
                    else:
                        fut.set_result(msg.get("result"))
            elif kind == "own_refused":
                # another worker holds a newer lease on these pks — our
                # in-memory copies are zombies and will self-fence at
                # their next store write; stop claiming them
                _metrics.get_registry().counter(
                    "broker.own_refused").inc(len(msg.get("pks", [])))
                for pk in msg.get("pks", []):
                    self._pk_epochs.pop(int(pk), None)
                logger.warning("ownership claim refused (stale epoch) for"
                               " pks %s", msg.get("pks"))
            elif kind == "broadcast":
                self._dispatch_broadcast(msg)
            elif kind == "broadcast_batch":
                for event in msg.get("events", []):
                    self._dispatch_broadcast(event)

    def _dispatch_broadcast(self, msg: dict) -> None:
        _metrics.get_registry().counter("broker.broadcasts_received").inc()
        for filt, handler in list(self._broadcast_handlers.values()):
            if filt and not fnmatch.fnmatch(msg["subject"], filt):
                continue
            try:
                handler(msg["subject"], msg.get("sender"),
                        msg.get("body", {}))
            except Exception:  # noqa: BLE001
                logger.exception("broadcast handler failed")

    async def _run_task(self, msg: dict) -> None:
        handler = self._task_handlers.get(msg["queue"])
        task_id = msg["task_id"]
        if handler is None:
            self._send({"kind": "nack", "task_id": task_id,
                        "queue": msg["queue"]})
            return
        if task_id in self._active_tasks:
            # duplicated frame of a task we are already running (an
            # at-least-once transport is allowed to do this): drop it —
            # the original execution's eventual ack/nack settles the row
            _metrics.get_registry().counter("broker.duplicate_frames").inc()
            return
        self._active_tasks.add(task_id)
        payload = msg["payload"]
        if isinstance(payload, dict) and "epoch" in payload and \
                "pk" in payload:
            # remember the lease epoch this frame was fenced under so a
            # reconnect re-claims ownership with a validated epoch
            self._pk_epochs[int(payload["pk"])] = int(payload["epoch"])
        try:
            await handler(msg["payload"])
            # crash seam: the work is done (and durable) but the broker
            # does not know — dying here forces a redelivery that the
            # task handler must recognise as already-finished
            chaos.fault_point("broker.ack.pre", task_id=task_id)
            self._send({"kind": "ack", "task_id": task_id})
        except Exception:  # noqa: BLE001
            logger.exception("task failed; nacking for requeue")
            self._send({"kind": "nack", "task_id": task_id,
                        "queue": msg["queue"]})
        finally:
            self._active_tasks.discard(task_id)

    async def _run_rpc(self, msg: dict) -> None:
        handler = self._rpc_handlers.get(msg["identifier"])
        reply: dict = {"kind": "rpc_reply", "rid": msg["rid"]}
        if handler is None:
            reply["error"] = f"no handler {msg['identifier']!r}"
        else:
            try:
                res = handler(msg["msg"])
                if asyncio.iscoroutine(res):
                    res = await res
                reply["result"] = res
            except asyncio.CancelledError:
                # broker-side deadline fired: it already answered the
                # caller with `cancelled`; nothing to reply
                return
            except Exception as exc:  # noqa: BLE001
                reply["error"] = repr(exc)
        self._send(reply)

    # -- Communicator interface ---------------------------------------------------
    def add_rpc_subscriber(self, identifier: str, handler: Callable) -> None:
        self._rpc_handlers[identifier] = handler
        m = _PROCESS_ID.match(identifier)
        if m is not None:
            self._queue_ownership(int(m.group(1)), True)
        else:
            self._send({"kind": "rpc_register", "identifier": identifier})

    def remove_rpc_subscriber(self, identifier: str) -> None:
        self._rpc_handlers.pop(identifier, None)
        m = _PROCESS_ID.match(identifier)
        if m is not None:
            self._queue_ownership(int(m.group(1)), False)
        else:
            self._send({"kind": "rpc_unregister", "identifier": identifier})

    async def rpc_lookup(self, pattern: str = "*") -> list[str]:
        """Query the broker's live RPC-identifier directory."""
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        if not self._send({"kind": "rpc_lookup", "rid": rid,
                           "pattern": pattern}):
            self._rpc_waiters.pop(rid, None)
            raise ConnectionError("broker connection lost")
        return await fut

    async def subscription_barrier(self) -> None:
        """Resolve once every frame already sent on this connection (e.g.
        a ``subscribe``) has been processed by the broker. Waiters use
        this to close the subscribe-then-check race under subject-filter
        pushdown."""
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        if not self._send({"kind": "sub_sync", "rid": rid}):
            self._rpc_waiters.pop(rid, None)
            raise ConnectionError("broker connection lost")
        await fut

    async def rpc_send_async(self, identifier: str, msg: dict,
                             timeout: float | None = None) -> Any:
        rid = str(uuid.uuid4())
        fut = asyncio.get_running_loop().create_future()
        self._rpc_waiters[rid] = fut
        frame = {"kind": "rpc_send", "rid": rid,
                 "identifier": identifier, "msg": msg}
        if timeout is not None:
            # server-side deadline: the broker cancels the handler and
            # replies `cancelled` (surfaced here as TimeoutError)
            frame["timeout"] = timeout
        t0 = time.perf_counter()
        with trace.span("broker.rpc", identifier=identifier):
            if not self._send(frame):
                self._rpc_waiters.pop(rid, None)
                raise ConnectionError("broker connection lost")
            result = await fut
        _metrics.get_registry().histogram("broker.rpc_seconds").observe(
            time.perf_counter() - t0)
        return result

    def rpc_send(self, identifier: str, msg: dict,
                 timeout: float | None = None) -> Any:
        return self.rpc_send_async(identifier, msg, timeout=timeout)

    def add_broadcast_subscriber(self, handler: Callable,
                                 subject_filter: str | None = None) -> int:
        token = next(self._bc_counter)
        self._broadcast_handlers[token] = (subject_filter, handler)
        pattern = subject_filter or "*"
        self._bc_patterns[pattern] = self._bc_patterns.get(pattern, 0) + 1
        if self._bc_patterns[pattern] == 1:
            # push the filter down: the broker only fans matching events
            self._send({"kind": "subscribe", "patterns": [pattern]})
        return token

    def remove_broadcast_subscriber(self, token: int) -> None:
        entry = self._broadcast_handlers.pop(token, None)
        if entry is None:
            return
        pattern = entry[0] or "*"
        count = self._bc_patterns.get(pattern, 0) - 1
        if count <= 0:
            self._bc_patterns.pop(pattern, None)
            self._send({"kind": "unsubscribe", "patterns": [pattern]})
        else:
            self._bc_patterns[pattern] = count

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        _metrics.get_registry().counter("broker.broadcasts_sent").inc()
        self._send({"kind": "broadcast", "subject": subject,
                    "sender": sender, "body": body or {}})

    def task_send(self, queue: str, payload: dict) -> None:
        self._send({"kind": "task_send", "queue": queue, "payload": payload})

    def task_send_many(self, queue: str, payloads: list[dict],
                       submitter: str | None = None) -> None:
        """Enqueue many payloads in one frame (one insert batch + one
        delivery round server-side)."""
        self._send({"kind": "task_send_many", "queue": queue,
                    "payloads": list(payloads), "submitter": submitter})

    def add_task_subscriber(self, queue: str,
                            handler: Callable[[dict], Awaitable],
                            prefetch: int = 1) -> None:
        self._task_handlers[queue] = handler
        self._task_prefetch[queue] = max(1, prefetch)
        self._send({"kind": "consume", "queue": queue,
                    "prefetch": self._task_prefetch[queue]})

    def close(self) -> None:
        try:
            self._flush_outbox()
        except Exception:  # noqa: BLE001
            pass
        for t in self._tasks:
            t.cancel()
        for t in list(self._rpc_tasks.values()):
            t.cancel()
        self._rpc_tasks.clear()
        if self._writer is not None:
            self._writer.close()


class SyncBrokerClient:
    """Blocking broker client for non-async callers (the CLI, tests).

    Speaks the same newline-JSON protocol as :class:`BrokerClient` but over
    a plain socket, sending heartbeats while idle so the broker's reaper
    does not presume it dead during a long ``watch``."""

    def __init__(self, host: str, port: int, connect_timeout: float = 10.0):
        self.host = host
        self.port = port
        self.connect_timeout = connect_timeout
        self._sock: socket.socket | None = None
        self._buf = b""
        self._last_beat = 0.0
        # broadcasts that arrived interleaved with an RPC reply; a later
        # events() call must still see them
        self._pending: list[dict] = []
        self._connect()

    def _connect(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout)
        self._sock.settimeout(0.25)
        self._buf = b""
        self._last_beat = 0.0

    def _send(self, msg: dict) -> None:
        try:
            self._sock.sendall(_encode(msg))
        except OSError as exc:
            raise ConnectionError("broker connection lost") from exc

    def _heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_beat >= 0.5:
            self._send({"kind": "heartbeat"})
            self._last_beat = now

    def _recv(self, deadline: float | None) -> dict | None:
        """Next message, or None once the deadline passes."""
        while True:
            # heartbeat even while draining buffered lines (e.g. a long
            # replay): the broker's reaper must keep seeing us alive
            self._heartbeat()
            if b"\n" in self._buf:
                line, self._buf = self._buf.split(b"\n", 1)
                if line.strip():
                    return json.loads(line)
                continue
            if deadline is not None and time.monotonic() > deadline:
                return None
            try:
                chunk = self._sock.recv(65536)
            except TimeoutError:
                continue
            except OSError as exc:
                raise ConnectionError("broker connection lost") from exc
            if not chunk:
                raise ConnectionError("broker closed the connection")
            self._buf += chunk

    def _stash_broadcast(self, msg: dict) -> None:
        if msg.get("kind") == "broadcast":
            self._pending.append(msg)
        elif msg.get("kind") == "broadcast_batch":
            self._pending.extend({"kind": "broadcast", **event}
                                 for event in msg.get("events", []))

    def _await_reply(self, rid: str, timeout: float) -> Any:
        deadline = time.monotonic() + timeout
        while True:
            msg = self._recv(deadline)
            if msg is None:
                raise TimeoutError(f"no broker reply within {timeout}s")
            if msg.get("kind") == "rpc_reply" and msg.get("rid") == rid:
                if msg.get("cancelled"):
                    raise TimeoutError(msg.get("error", "rpc cancelled"))
                if "error" in msg:
                    raise KeyError(msg["error"])
                return msg.get("result")
            # e.g. the state change a control intent provoked landing
            # before its rpc_reply — keep it for the next events() call
            self._stash_broadcast(msg)

    def _request(self, build_msg, timeout: float) -> Any:
        """Send a request and await its reply, reconnecting under a
        full-jitter backoff schedule (engine/backoff.py) on connection
        loss — this covers both the broker reaping an idle client (2
        missed heartbeats) and a broker *restart window*, during which
        connects are refused until the supervising daemon brings it back.
        Control intents are idempotent, so the retry is safe."""
        from repro.engine.backoff import (
            TransportTaskExhausted, retry_sync,
        )
        state = {"fresh": self._sock is not None}

        def attempt():
            if not state["fresh"]:
                self._connect()
                state["fresh"] = True
            rid = str(uuid.uuid4())
            try:
                self._send(build_msg(rid))
                return self._await_reply(rid, timeout)
            except ConnectionError:
                state["fresh"] = False
                raise

        try:
            return retry_sync(attempt, initial_interval=0.2, max_attempts=6,
                              name="sync-broker-request",
                              non_retryable=(TimeoutError, KeyError))
        except TransportTaskExhausted as exc:
            # callers' error handling predates the backoff wrapper: keep
            # surfacing the underlying connection failure
            raise exc.last from exc

    def rpc(self, identifier: str, msg: dict, timeout: float = 10.0) -> Any:
        # the broker enforces the deadline server-side (cancelled reply);
        # the local await gets slack so the server verdict wins the race
        return self._request(
            lambda rid: {"kind": "rpc_send", "rid": rid,
                         "identifier": identifier, "msg": msg,
                         "timeout": timeout}, timeout + 2.0)

    def lookup(self, pattern: str = "*", timeout: float = 10.0) -> list[str]:
        return self._request(
            lambda rid: {"kind": "rpc_lookup", "rid": rid,
                         "pattern": pattern}, timeout)

    def task_send(self, queue: str, payload: dict,
                  submitter: str | None = None,
                  timeout: float = 30.0) -> int:
        """Enqueue one task and wait for the broker's durable-delivery
        ack (replaces the old fire-and-sleep submission path)."""
        return self._request(
            lambda rid: {"kind": "task_send", "rid": rid, "queue": queue,
                         "payload": payload, "submitter": submitter},
            timeout)

    def task_send_many(self, queue: str, payloads: list[dict],
                       submitter: str | None = None,
                       timeout: float = 60.0) -> int:
        """Enqueue many tasks in one frame; returns the acked count."""
        payloads = list(payloads)
        return self._request(
            lambda rid: {"kind": "task_send_many", "rid": rid,
                         "queue": queue, "payloads": payloads,
                         "submitter": submitter}, timeout)

    def broker_stats(self, timeout: float = 10.0) -> dict:
        """The broker's control-plane traffic counters + queue depths."""
        return self._request(
            lambda rid: {"kind": "broker_stats", "rid": rid}, timeout)

    def broadcast_send(self, subject: str, sender: Any = None,
                       body: dict | None = None) -> None:
        self._send({"kind": "broadcast", "subject": subject,
                    "sender": sender, "body": body or {}})

    def events(self, subject_filter: str | None = None,
               timeout: float | None = None,
               replay_since: int | None = None
               ) -> Iterator[tuple[str, Any, dict]]:
        """Yield ``(subject, sender, body)`` broadcasts as they arrive;
        stops after ``timeout`` seconds of total watching (None = forever).
        ``replay_since`` first replays logged events with seq > the given
        value (0 = everything the broker still remembers)."""
        pattern = subject_filter or "*"
        # subject-filter pushdown: tell the broker to fan matching live
        # events to us (without this, it sends nothing at all)
        self._send({"kind": "subscribe", "patterns": [pattern]})
        if replay_since is not None:
            self._send({"kind": "events_since", "seq": replay_since,
                        "pattern": subject_filter})
        deadline = None if timeout is None else time.monotonic() + timeout
        # replay + live can overlap around the events_since request; the
        # broker stamps every event with a unique seq — dedup on it, but
        # only until the replay catches up (keeps `seen` bounded on
        # long-lived watches)
        seen: set[int] = set()
        replaying = replay_since is not None
        try:
            while True:
                if self._pending:
                    msg = self._pending.pop(0)
                else:
                    msg = self._recv(deadline)
                if msg is None:
                    return
                if msg.get("kind") == "events_caught_up":
                    replaying = False
                    seen.clear()
                    continue
                if msg.get("kind") == "broadcast_batch":
                    self._pending = [
                        {"kind": "broadcast", **event}
                        for event in msg.get("events", [])] + self._pending
                    continue
                if msg.get("kind") != "broadcast":
                    continue
                seq = msg.get("seq")
                if replaying and seq is not None:
                    if seq in seen:
                        continue
                    seen.add(seq)
                subject = msg["subject"]
                if subject_filter and not fnmatch.fnmatch(subject,
                                                          subject_filter):
                    continue
                yield subject, msg.get("sender"), msg.get("body", {})
        finally:
            try:
                self._send({"kind": "unsubscribe", "patterns": [pattern]})
            except ConnectionError:
                pass

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
