"""The daemon (paper §III.A.1 — the Circus role).

Spawns and supervises: one broker process (the RabbitMQ role) and N worker
processes, each running one Runner with S process slots — scaling is
horizontal × vertical = workers × slots (paper fig. 5). Crashed workers are
restarted; their in-flight tasks are requeued by the broker heartbeat
reaper, and the replacement worker resumes the processes from their last
checkpoints.
"""

from __future__ import annotations

import asyncio
import importlib
import json
import logging
import multiprocessing as mp
import os
import time
from typing import Any

from repro.chaos import faults as chaos
from repro.engine.runner import TERMINAL
from repro.observability import logs as obs_logs
from repro.observability import metrics as _metrics
from repro.observability import trace
from repro.provenance.store import SUMMARY_COLUMNS, StaleEpochError

logger = logging.getLogger("repro.engine.daemon")

PROCESS_QUEUE = "process.queue"

#: pickup latency can legitimately reach minutes when 100k tasks queue
#: behind 10k live slots — extend the default buckets so p99 stays
#: computable at saturation
PICKUP_BUCKETS = _metrics.DEFAULT_BUCKETS + (60.0, 120.0, 300.0, 600.0)


# ---------------------------------------------------------------------------
# Worker main
# ---------------------------------------------------------------------------

def make_process_task_handler(runner, store, owned: set | None = None):
    """The worker's task-queue handler: resume one process from its
    checkpoint and drive it to termination. ``owned`` (when given) tracks
    the pks this worker currently runs — advertised over the worker's own
    RPC endpoint. Factored out so tests can exercise the exact
    resume/kill-durability path without spawning OS processes."""
    from repro.core.process import Process

    #: pks this handler is currently driving — a second delivery of the
    #: same pk (duplicate task row after a partition/requeue race) must
    #: not run the process twice concurrently. Returning early is safe:
    #: the duplicate's own task row gets acked while the original row
    #: stays inflight until the real execution settles.
    running: set[int] = set()

    async def handle(payload: dict) -> None:
        pk = payload["pk"]
        registry = _metrics.get_registry()
        registry.counter("daemon.tasks").inc()
        sent_ts = payload.get("ts")
        if sent_ts is not None:
            # submit→pickup latency: how long the task sat in the queue
            registry.histogram("daemon.pickup_seconds",
                               buckets=PICKUP_BUCKETS).observe(
                max(0.0, time.time() - sent_ts))
        if pk in running:
            registry.counter("daemon.duplicate_tasks").inc()
            return
        # slot-gate BEFORE materializing the Process: tasks delivered
        # beyond the slot count wait here as pk-only payloads, so resident
        # Process objects (checkpoint, inputs, namespaces) stay bounded by
        # the slot count — worker RSS does not grow with the backlog
        async with runner._sem():
            if pk in running:
                registry.counter("daemon.duplicate_tasks").inc()
                return
            chaos.fault_point("daemon.checkpoint.pre", pk=pk)
            # fence FIRST: record this delivery's lease epoch in the store
            # before doing any work. From here on, any holder of an older
            # epoch (a zombie whose lease lapsed and was requeued to us)
            # has its flush/terminal writes rejected. A delivery that is
            # itself stale (the pk was re-leased past us while this frame
            # sat in the socket) self-rejects here and just acks.
            epoch = payload.get("epoch")
            if epoch is not None:
                try:
                    store.fence_epoch(pk, int(epoch))
                except StaleEpochError:
                    registry.counter("daemon.stale_deliveries").inc()
                    return
                except KeyError:
                    raise RuntimeError(f"no node for process {pk}") from None
            checkpoint = store.load_checkpoint(pk)
            if checkpoint is None:
                node = store.get_node(pk, columns=SUMMARY_COLUMNS)
                if node and node.get("process_state") in TERMINAL:
                    return  # duplicate delivery of a finished process
                raise RuntimeError(f"no checkpoint for process {pk}")
            with trace.span("daemon.resume", pk=pk):
                process = Process.recreate_from_checkpoint(
                    checkpoint, runner=runner,
                    epoch=int(epoch) if epoch is not None else None)
            # rematerialized, first step not taken — the canonical
            # kill-9-mid-step window the paper's robustness story covers
            chaos.fault_point("daemon.checkpoint.post", pk=pk)
            running.add(pk)
            if owned is not None:
                owned.add(pk)
            registry.gauge("daemon.resident_processes").inc()
            try:
                # step_until_terminated registers process.<pk> RPC itself
                # and honours a durably-recorded kill before doing any work
                with obs_logs.pk_context(pk):
                    await process.step_until_terminated()
            finally:
                registry.gauge("daemon.resident_processes").dec()
                running.discard(pk)
                if owned is not None:
                    owned.discard(pk)

    return handle


def _rss_kb() -> int:
    """This process's resident set size in kB (0 where /proc is absent)."""
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1])
    except (OSError, ValueError, IndexError):
        pass
    return 0


def _worker_main(broker_host: str, broker_port: int, store_path: str,
                 slots: int, crash_after: float | None = None) -> None:
    """Entry point of one daemon worker OS process."""
    import random
    import uuid

    from repro.engine.broker import BrokerClient
    from repro.engine.runner import Runner, set_default_runner
    from repro.provenance.store import configure_store

    obs_logs.configure()  # honours REPRO_LOG_LEVEL; repro.* namespace only
    store = configure_store(store_path)

    async def main() -> None:
        # the stable worker NAME is the lease identity: it survives a
        # reconnect (same worker, new socket), so the broker can tell a
        # reconnecting holder from a replacement and only bump epochs for
        # genuine hand-offs
        worker_id = f"worker.{os.getpid()}-{uuid.uuid4().hex[:6]}"
        client = BrokerClient(broker_host, broker_port,
                              worker_name=worker_id)
        await client.connect()
        # REPRO_LIVENESS_INTERVAL shortens the store-recheck fallback that
        # papers over lost terminal broadcasts (chaos partition scenarios)
        liveness = float(os.environ.get("REPRO_LIVENESS_INTERVAL", "30"))
        runner = Runner(store=store, communicator=client, slots=slots,
                        liveness_interval=liveness)
        runner.distributed = True
        set_default_runner(runner)

        # advertise this worker + the pks it owns (control-plane directory);
        # the advert doubles as the worker's metrics publication — `repro
        # stats`/`repro process top` merge these snapshots client-side
        obs_logs.set_worker_id(worker_id)
        owned: set[int] = set()
        client.add_rpc_subscriber(
            worker_id,
            lambda msg: {"worker": worker_id, "pid": os.getpid(),
                         "slots": slots, "pks": sorted(owned),
                         "resident": len(owned), "rss_kb": _rss_kb(),
                         "metrics": _metrics.get_registry().snapshot()})

        # prefetch = slots: the broker parks anything beyond the worker's
        # concurrency in the durable queue (ready-queue high-water mark)
        client.add_task_subscriber(
            PROCESS_QUEUE, make_process_task_handler(runner, store, owned),
            prefetch=slots)
        if crash_after is not None:
            # fault-injection for tests: die hard mid-work
            await asyncio.sleep(crash_after + random.random() * 0.1)
            os._exit(17)
        while True:
            await asyncio.sleep(3600)

    asyncio.run(main())


def _broker_main(db_path: str, port_file: str,
                 heartbeat: float = 1.0, port: int = 0) -> None:
    """Broker OS process. A non-zero ``port`` pins the listen address —
    the daemon restarts a crashed broker on the SAME port so connected
    workers and submitters reconnect without rediscovery; the replacement
    rebuilds leases/tasks from the broker sqlite (``_recover``)."""
    from repro.engine.broker import BrokerServer

    obs_logs.configure()

    async def main() -> None:
        server = BrokerServer(db_path, port=port, heartbeat=heartbeat)
        host, bound = await server.start()
        with open(port_file, "w") as fh:
            json.dump({"host": host, "port": bound}, fh)
        while True:
            await asyncio.sleep(3600)

    asyncio.run(main())


# ---------------------------------------------------------------------------
# The daemon supervisor
# ---------------------------------------------------------------------------

class Daemon:
    def __init__(self, workdir: str, *, workers: int = 2, slots: int = 50,
                 store_path: str | None = None,
                 crash_after: float | None = None,
                 heartbeat: float = 1.0):
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.store_path = store_path or os.path.join(workdir, "provenance.db")
        self.broker_db = os.path.join(workdir, "broker.db")
        self.port_file = os.path.join(workdir, "broker.json")
        self.n_workers = workers
        self.slots = slots
        self.crash_after = crash_after
        # liveness window: a worker missing 2x this is presumed dead and
        # its in-flight tasks requeued. Raise it for saturation workloads
        # where thousands of simultaneous resumes can starve a worker's
        # heartbeat task for seconds without the worker being dead.
        self.heartbeat = heartbeat
        self._ctx = mp.get_context("spawn")
        self._broker_proc: mp.Process | None = None
        self._workers: list[mp.Process] = []
        self.host: str | None = None
        self.port: int | None = None
        self.broker_restarts = 0
        self._submit_client = None
        self.submitter_id = f"daemon-{os.getpid()}"

    # -- lifecycle ---------------------------------------------------------------
    def _spawn_broker(self, port: int, timeout: float) -> None:
        if os.path.exists(self.port_file):
            os.unlink(self.port_file)
        self._broker_proc = self._ctx.Process(
            target=_broker_main,
            args=(self.broker_db, self.port_file, self.heartbeat, port),
            daemon=True)
        self._broker_proc.start()
        t0 = time.time()
        while not os.path.exists(self.port_file):
            if time.time() - t0 > timeout:
                raise TimeoutError("broker did not start")
            time.sleep(0.05)
        time.sleep(0.05)
        with open(self.port_file) as fh:
            info = json.load(fh)
        self.host, self.port = info["host"], info["port"]

    def start(self, timeout: float = 20.0) -> None:
        self._spawn_broker(0, timeout)
        for i in range(self.n_workers):
            self._spawn_worker()

    def _spawn_worker(self) -> None:
        p = self._ctx.Process(
            target=_worker_main,
            args=(self.host, self.port, self.store_path, self.slots,
                  self.crash_after),
            daemon=True)
        p.start()
        self._workers.append(p)

    def supervise(self) -> int:
        """Restart dead workers AND a dead broker (the Circus role).
        Returns #restarts (workers + broker). A restarted broker is pinned
        to the old port, so live workers' reconnect loops find it without
        rediscovery and re-``own`` their pks with epoch validation."""
        restarts = 0
        if self._broker_proc is not None and not self._broker_proc.is_alive():
            logger.warning("broker died (exitcode %s); restarting on "
                           "port %s", self._broker_proc.exitcode, self.port)
            chaos.fault_point("broker.restart", port=self.port or 0)
            self._spawn_broker(self.port or 0, timeout=20.0)
            # the old submitter socket points at the dead process; drop it
            # so the next send reconnects (with full-jitter retries)
            if self._submit_client is not None:
                self._submit_client.close()
                self._submit_client = None
            self.broker_restarts += 1
            restarts += 1
        for i, p in enumerate(list(self._workers)):
            if not p.is_alive():
                logger.warning("worker %d died (exitcode %s); restarting",
                               i, p.exitcode)
                self._workers.remove(p)
                self._spawn_worker()
                restarts += 1
        return restarts

    def scale_workers(self, n: int) -> None:
        """Dynamically grow/shrink the pool (Circus 'incr')."""
        while len(self._workers) < n:
            self._spawn_worker()
        while len(self._workers) > n:
            p = self._workers.pop()
            p.terminate()
        self.n_workers = n

    def worker_pids(self) -> list[int]:
        """OS pids of the live worker processes (e.g. for RSS sampling)."""
        return [p.pid for p in self._workers if p.is_alive()]

    def stop(self) -> None:
        if self._submit_client is not None:
            self._submit_client.close()
            self._submit_client = None
        for p in self._workers:
            p.terminate()
        if self._broker_proc is not None:
            self._broker_proc.terminate()
        for p in self._workers:
            p.join(timeout=5)
        if self._broker_proc is not None:
            self._broker_proc.join(timeout=5)

    # -- client-side submission ---------------------------------------------------
    def submit(self, process_class, inputs: dict | None = None) -> int:
        """Create the process node + initial checkpoint locally, then ship
        the pk through the durable task queue (paper §III.C.a). Accepts a
        Process class + inputs or a ProcessBuilder, like engine/launch.py."""
        from repro.core.builder import expand_launch_target
        from repro.engine.runner import Runner
        from repro.provenance.store import configure_store, current_store

        process_class, inputs = expand_launch_target(process_class, inputs)
        store = current_store()
        if store.path != self.store_path:
            store = configure_store(self.store_path)
        runner = Runner(store=store)
        process = process_class(inputs=inputs, runner=runner)
        pk = process.pk
        self.send_task(pk)
        return pk

    def _submitter(self):
        """One persistent broker connection for all submissions (the old
        path opened a fresh socket and slept 50 ms per task)."""
        if self._submit_client is None:
            from repro.engine.broker import SyncBrokerClient
            self._submit_client = SyncBrokerClient(self.host, self.port)
        return self._submit_client

    def send_task(self, pk: int) -> None:
        """Ship one pk through the durable queue; returns once the broker
        acks the durable enqueue (no sleep, no per-task socket)."""
        self._submitter().task_send(
            PROCESS_QUEUE, {"pk": pk, "ts": time.time()},
            submitter=self.submitter_id)

    def send_tasks(self, pks, chunk: int = 1000) -> int:
        """Batch-ship many pks: ``task_send_many`` frames of ``chunk``
        payloads, each acked as one durable insert. Returns the count."""
        client = self._submitter()
        pks = list(pks)
        sent = 0
        for i in range(0, len(pks), chunk):
            now = time.time()
            sent += client.task_send_many(
                PROCESS_QUEUE,
                [{"pk": pk, "ts": now} for pk in pks[i:i + chunk]],
                submitter=self.submitter_id)
        return sent

    def controller(self):
        """A synchronous control-plane client for this daemon's broker
        (pause/play/kill/status/watch — the `repro process` verbs)."""
        from repro.engine.controller import ProcessController
        return ProcessController(self.host, self.port)
