"""Jit'd wrapper for the flash-decode kernel (forward only — decode has no
backward pass)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention import kernel as K


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_kv: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, H, hd); k/v: (B, Smax, Hkv, hd); kv_len: (B,) or scalar."""
    if scale is None:
        scale = 1.0 / float(q.shape[-1]) ** 0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return K.decode_attention_kernel(q, k, v, kv_len, scale=float(scale),
                                     block_kv=int(block_kv),
                                     interpret=bool(interpret))
