"""Flash-decode Pallas kernel: one query token against a deep KV cache.

Decode is HBM-bandwidth bound (the whole cache is read once per token); the
kernel streams KV blocks through VMEM with the online-softmax recurrence,
grid = (B, Hkv, nKV) with the KV axis innermost/sequential. All G query
heads of a KV group are processed together so the cache is read ONCE per
group (the GQA arithmetic-intensity win). Per-row cache lengths arrive via
scalar prefetch (SMEM), letting one batch mix ragged sequence lengths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, nkv, bkv):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = lens_ref[ib]
    needed = (ik * bkv) < kv_len

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bkv, hd)
        logits = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        pos = ik * bkv + lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
        logits = jnp.where(pos < kv_len, logits, NEG_INF)  # (G, bkv)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        pv = lax.dot_general(p.astype(v_ref.dtype), v_ref[0, 0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, kv_len, *, scale, block_kv, interpret):
    """q: (B, H, hd); k/v: (B, Smax, Hkv, hd); kv_len: (B,) int32."""
    b, h, hd = q.shape
    smax, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bkv = min(block_kv, smax)
    while smax % bkv:
        bkv //= 2
    nkv = smax // bkv

    qg = q.reshape(b, hkv, g, hd)
    kt = k.transpose(0, 2, 1, 3)    # (B, Hkv, Smax, hd)
    vt = v.transpose(0, 2, 1, 3)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))

    kernel = functools.partial(_kernel, scale=scale, nkv=nkv, bkv=bkv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nkv),
        in_specs=[
            # index maps receive the scalar-prefetch ref as a trailing arg
            pl.BlockSpec((1, 1, g, hd),
                         lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, lens: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, lens: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(lens, qg, kt, vt)
    return out.reshape(b, h, hd)
