"""Oracle for single-token flash decode over a long KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array | int, *,
                         scale: float | None = None) -> jax.Array:
    """q: (B, H, hd) one token; k/v: (B, Smax, Hkv, hd); kv_len: (B,) or int.

    Attends to cache positions [0, kv_len) per batch row."""
    b, h, hd = q.shape
    smax, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    qg = q.reshape(b, hkv, g, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    lens = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
    ok = jnp.arange(smax)[None, :] < lens[:, None]            # (B, Smax)
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p.astype(v.dtype), v)
    return out.reshape(b, h, hd).astype(q.dtype)
