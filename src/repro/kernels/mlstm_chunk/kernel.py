"""Chunkwise-parallel mLSTM Pallas kernel (xLSTM matrix memory).

Grid (B, H, nChunks) with the chunk axis innermost/sequential; the
inter-chunk state (C: hd×hd matrix memory, n: hd normalizer, m: scalar
stabiliser) persists in VMEM scratch. Intra-chunk work is two MXU matmuls
(qk^T and the dv-style combine) over an L×L decay-masked score matrix —
the TPU-native replacement for the paper's fused CUDA recurrence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -1.0e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, c0_ref, n0_ref, m0_ref,
            h_ref, cf_ref, nf_ref, mf_ref, C_scr, n_scr, m_scr, *,
            nc, L, hd, scale):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        C_scr[...] = c0_ref[0, 0].astype(jnp.float32)
        n_scr[...] = n0_ref[0, 0].astype(jnp.float32)
        m_scr[0, 0] = jnp.maximum(m0_ref[0, 0], NEG_BIG)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (L, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)             # (L,)
    lf = lf_ref[0, 0].astype(jnp.float32)

    b_cum = jnp.cumsum(lf)                            # (L,) inclusive
    total = b_cum[L - 1]
    m_prev = m_scr[0, 0]
    C_prev = C_scr[...]
    n_prev = n_scr[...]

    # intra-chunk decay matrix D[t, s] = b_t - b_s + li_s for s <= t
    tri = lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        lax.broadcasted_iota(jnp.int32, (L, L), 0)
    D = b_cum[:, None] - b_cum[None, :] + li[None, :]
    D = jnp.where(tri, D, NEG_BIG)
    m_intra = jnp.max(D, axis=1)                      # (L,)
    m_inter = b_cum + m_prev
    m_out = jnp.maximum(jnp.maximum(m_intra, m_inter), NEG_BIG)

    inter_scale = jnp.exp(m_inter - m_out)            # (L,)
    h_inter = lax.dot_general(q, C_prev, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den_inter = lax.dot_general(q, n_prev.reshape(hd, 1),
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)[:, 0]

    P = jnp.exp(D - m_out[:, None])
    att = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                          preferred_element_type=jnp.float32) * P
    h_intra = lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    den_intra = jnp.sum(att, axis=1)
    num = h_inter * inter_scale[:, None] + h_intra
    den = den_inter * inter_scale + den_intra
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
    h_ref[0, 0] = (num / denom[:, None]).astype(h_ref.dtype)

    # inter-chunk state update with per-chunk stabiliser
    m_cand = jnp.max(li + total - b_cum)
    m_new = jnp.maximum(m_prev + total, m_cand)
    c_scale = jnp.exp(m_prev + total - m_new)
    k_scale = jnp.exp(li + total - b_cum - m_new)     # (L,)
    kv = lax.dot_general(k * k_scale[:, None], v, (((0,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)   # (hd, hd)
    C_scr[...] = C_prev * c_scale + kv
    n_scr[...] = n_prev * c_scale + jnp.sum(k * k_scale[:, None], axis=0)
    m_scr[0, 0] = m_new

    @pl.when(ic == nc - 1)
    def _final():
        cf_ref[0, 0] = C_scr[...]
        nf_ref[0, 0] = n_scr[...]
        mf_ref[0, 0] = m_scr[0, 0]


def mlstm_chunk_kernel(q, k, v, li, lf, C0, n0, m0, *, chunk, interpret):
    """q/k/v: (B, H, S, hd); li/lf: (B, H, S); state C0 (B,H,hd,hd),
    n0 (B,H,hd), m0 (B,H). NOTE: initial state must be zeros/-inf (the
    kernel re-initialises); non-trivial initial state is handled by ops.py.
    """
    b, h, s, hd = q.shape
    L = min(chunk, s)
    while s % L:
        L //= 2
    nc = s // L
    scale = 1.0 / float(hd) ** 0.5

    kernel = functools.partial(_kernel, nc=nc, L=L, hd=hd, scale=scale)
    hs, cf, nf, mf = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, L), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, hd, hd), lambda ib, ih, ic: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda ib, ih, ic: (ib, ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ib, ih)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda ib, ih, ic: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda ib, ih, ic: (ib, ih, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ib, ih)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf, C0.astype(jnp.float32), n0.astype(jnp.float32),
      m0.astype(jnp.float32))
    return hs, (cf, nf, mf)
