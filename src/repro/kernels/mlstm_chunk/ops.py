"""Jit'd wrapper for the chunkwise mLSTM kernel. The carried state
(C0, n0, m0) is a first-class kernel input, so prefill continuations are
exact with no host-side correction."""

from __future__ import annotations

import jax

from repro.kernels.mlstm_chunk import kernel as K


def mlstm_chunk(q, k, v, li, lf, C0, n0, m0, *, chunk: int = 128,
                interpret: bool | None = None):
    """Chunkwise mLSTM. Shapes as in models.xlstm.mlstm_chunkwise.

    Returns (h: (B,H,S,hd), (C, n, m) final state, fp32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return K.mlstm_chunk_kernel(q, k, v, li, lf, C0, n0, m0,
                                chunk=int(chunk), interpret=bool(interpret))
