"""Oracle for the chunkwise mLSTM kernel: the exact sequential recurrence
(identical math to repro.models.xlstm.mlstm_recurrent_ref, re-exported here
so the kernel package is self-contained)."""

from repro.models.xlstm import mlstm_recurrent_ref  # noqa: F401


def mlstm_ref(q, k, v, li, lf, C0, n0, m0):
    return mlstm_recurrent_ref(q, k, v, li, lf, C0, n0, m0)
