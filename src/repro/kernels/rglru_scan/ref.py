"""Oracle for the RG-LRU linear-recurrence kernel: exact sequential scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rglru_scan_ref(a: jax.Array, x: jax.Array, h0: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + x_t. a, x: (B, S, D) fp32; h0: (B, D).

    Returns (h for every t, final h)."""

    def step(h, inputs):
        at, xt = inputs
        h = at * h + xt
        return h, h

    a_t = a.astype(jnp.float32).transpose(1, 0, 2)
    x_t = x.astype(jnp.float32).transpose(1, 0, 2)
    h_last, hs = lax.scan(step, h0.astype(jnp.float32), (a_t, x_t))
    return hs.transpose(1, 0, 2), h_last
