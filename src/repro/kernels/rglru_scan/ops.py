"""Jit'd wrapper for the RG-LRU scan kernel with a custom VJP.

The linear recurrence has a closed-form adjoint which is itself a linear
recurrence run backwards:
    dL/dx_t = g_t,  where  g_t = dL/dh_t + a_{t+1} * g_{t+1}
    dL/da_t = g_t * h_{t-1}
    dL/dh0  = a_1 * g_1
so the same kernel (time-reversed) computes the backward pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _scan(a, x, h0, block_t, block_d, interpret):
    return K.rglru_scan_kernel(a, x, h0, block_t=block_t, block_d=block_d,
                               interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _rglru(a, x, h0, block_t, block_d, interpret):
    hs, h_last = _scan(a, x, h0, block_t, block_d, interpret)
    return hs, h_last


def _fwd(a, x, h0, block_t, block_d, interpret):
    hs, h_last = _scan(a, x, h0, block_t, block_d, interpret)
    return (hs, h_last), (a, hs, h0)


def _bwd(block_t, block_d, interpret, res, grads):
    a, hs, h0 = res
    dhs, dh_last = grads
    b, s, d = a.shape
    # incorporate the gradient wrt the final state into the last step
    dhs = dhs.astype(jnp.float32).at[:, -1, :].add(dh_last.astype(jnp.float32))
    # reverse-time recurrence: g_t = dhs_t + a_{t+1} g_{t+1}
    a_rev = jnp.flip(jnp.concatenate(
        [a.astype(jnp.float32)[:, 1:, :], jnp.zeros((b, 1, d), jnp.float32)],
        axis=1), axis=1)
    g_rev, _ = _scan(a_rev, jnp.flip(dhs, axis=1),
                     jnp.zeros((b, d), jnp.float32), block_t, block_d,
                     interpret)
    g = jnp.flip(g_rev, axis=1)
    h_prev = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None, :], hs[:, :-1, :]], axis=1)
    da = g * h_prev
    dx = g
    dh0 = a.astype(jnp.float32)[:, 0, :] * g[:, 0, :]
    return da.astype(a.dtype), dx.astype(a.dtype), dh0.astype(h0.dtype)


_rglru.defvjp(_fwd, _bwd)


def rglru_scan(a, x, h0, *, block_t: int = 128, block_d: int = 512,
               interpret: bool | None = None):
    """h_t = a_t*h_{t-1} + x_t, blocked for TPU. Returns (hs, h_last)."""
    if interpret is None:
        interpret = _interpret_default()
    return _rglru(a, x, h0, int(block_t), int(block_d), bool(interpret))
