"""RG-LRU linear-recurrence Pallas kernel.

TPU adaptation of the GPU scan: no warp shuffles exist, so the recurrence
is blocked — grid (nD, nT) with the TIME axis innermost (sequential on
TPU); the carry h lives in VMEM scratch and persists across time blocks.
Inside a block the recurrence h_t = a_t*h_{t-1} + x_t is evaluated with a
log2(bt)-step Blelloch-style doubling on the VPU (dense (B, bt, dblk)
element-wise ops), which beats a bt-step serial loop on a vector unit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, h0_ref, hs_ref, hlast_ref, h_scr, *, nt, bt):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)     # (B, bt, dblk)
    x = x_ref[...].astype(jnp.float32)

    # in-block parallel prefix: after k rounds, for each t,
    #   x[t] = combined update over (t-2^k, t];  a[t] = product of decays
    k = 1
    while k < bt:
        a_shift = jnp.pad(a, ((0, 0), (k, 0), (0, 0)))[:, :bt]
        x_shift = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :bt]
        x = x + a * x_shift
        a = a * jnp.where(
            lax.broadcasted_iota(jnp.int32, a.shape, 1) >= k, a_shift, 1.0)
        k *= 2

    hs = x + a * h_scr[...][:, None, :]
    hs_ref[...] = hs.astype(hs_ref.dtype)
    h_scr[...] = hs[:, -1, :]

    @pl.when(it == nt - 1)
    def _final():
        hlast_ref[...] = h_scr[...]


def rglru_scan_kernel(a, x, h0, *, block_t, block_d, interpret):
    """a, x: (B, S, D); h0: (B, D) -> (hs (B,S,D) fp32, h_last (B,D) fp32)."""
    b, s, d = a.shape
    bt = min(block_t, s)
    while s % bt:
        bt //= 2
    bd = min(block_d, d)
    while d % bd:
        bd //= 2
    nt, nd = s // bt, d // bd

    kernel = functools.partial(_kernel, nt=nt, bt=bt)
    hs, h_last = pl.pallas_call(
        kernel,
        grid=(nd, nt),
        in_specs=[
            pl.BlockSpec((b, bt, bd), lambda idd, it: (0, it, idd)),
            pl.BlockSpec((b, bt, bd), lambda idd, it: (0, it, idd)),
            pl.BlockSpec((b, bd), lambda idd, it: (0, idd)),
        ],
        out_specs=[
            pl.BlockSpec((b, bt, bd), lambda idd, it: (0, it, idd)),
            pl.BlockSpec((b, bd), lambda idd, it: (0, idd)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((b, bd), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
    return hs, h_last
