"""Jit'd public wrapper for the flash attention kernel with custom VJP.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests; on TPU the compiled kernel runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def _flash(q, k, v, causal, window, scale, softcap, q_offset, block_q,
           block_kv, interpret):
    out, _ = K.flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, window, scale, softcap, q_offset, block_q,
               block_kv, interpret):
    out, lse = K.flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale, softcap=softcap,
        q_offset=q_offset, block_q=block_q, block_kv=block_kv,
        interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, softcap, q_offset, block_q, block_kv,
               interpret, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = K.flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window, scale=scale,
        softcap=softcap, q_offset=q_offset, block_q=block_q,
        block_kv=block_kv, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    q_offset: int = 0, block_q: int = 512,
                    block_kv: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd). Returns (B, Sq, H, hd)."""
    if scale is None:
        scale = 1.0 / float(q.shape[-1]) ** 0.5
    if interpret is None:
        interpret = _interpret_default()
    q_offset = int(q_offset) if not hasattr(q_offset, "shape") else 0
    return _flash(q, k, v, causal, window, float(scale), float(softcap),
                  q_offset, int(block_q), int(block_kv), bool(interpret))
