"""Flash attention Pallas TPU kernels (fwd + bwd).

TPU adaptation of the GPU flash-attention algorithm: instead of warp-level
tiles, blocks are sized for VMEM and the MXU's 128-lane systolic array.
The KV axis is the innermost *sequential* grid dimension, so the online
softmax state (m, l, acc) lives in VMEM scratch that persists across KV
steps of one (batch, head, q-block) program — the TPU analogue of a GPU
thread-block's shared-memory accumulator.

Grid (fwd): (B, H, nQ, nKV); K/V index_map folds GQA: kv_head = h // G.
Fully-masked KV blocks are skipped with pl.when (causal/local windows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _apply_softcap(logits, softcap):
    if softcap and softcap > 0.0:
        return softcap * jnp.tanh(logits / softcap)
    return logits


def _mask(bq, bkv, iq, ik, *, causal, window, q_offset):
    q_pos = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) + q_offset
    k_pos = ik * bkv + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    return ok


def _block_needed(bq, bkv, iq, ik, *, causal, window, q_offset):
    """Static-shape test: could any element of this (iq, ik) tile be live?"""
    need = jnp.bool_(True)
    if causal:
        # first k of block must be <= last q of block
        need &= (ik * bkv) <= (iq * bq + bq - 1 + q_offset)
    if window > 0:
        # last k of block must be > first q - window
        need &= (ik * bkv + bkv - 1) > (iq * bq + q_offset - window)
    return need


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, window, softcap, q_offset, nkv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    bq = q_ref.shape[2]
    bkv = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    needed = _block_needed(bq, bkv, iq, ik, causal=causal, window=window,
                           q_offset=q_offset)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)      # (bq, bkv)
        logits = _apply_softcap(logits, softcap)
        ok = _mask(bq, bkv, iq, ik, causal=causal, window=window,
                   q_offset=q_offset)
        logits = jnp.where(ok, logits, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(jnp.maximum(l, 1e-30)))


def flash_attention_fwd(q, k, v, *, causal, window, scale, softcap, q_offset,
                        block_q, block_kv, interpret):
    """q: (B, Sq, H, hd); k/v: (B, Skv, Hkv, hd) -> (out, lse)."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bkv = min(block_kv, skv)
    while skv % bkv:
        bkv //= 2
    nq, nkv = sq // bq, skv // bkv

    # layout: (B, H, S, hd) for q; (B, Hkv, S, hd) for k/v
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, nkv=nkv)

    out, lse = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3), lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_scr, *, scale, causal, window, softcap, q_offset, nkv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    bq = q_ref.shape[2]
    bkv = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    needed = _block_needed(bq, bkv, iq, ik, causal=causal, window=window,
                           q_offset=q_offset)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        if softcap and softcap > 0.0:
            t = jnp.tanh(raw / softcap)
            logits = softcap * t
            dcap = 1.0 - t * t
        else:
            logits = raw
            dcap = None
        ok = _mask(bq, bkv, iq, ik, causal=causal, window=window,
                   q_offset=q_offset)
        logits = jnp.where(ok, logits, NEG_INF)
        p = jnp.exp(logits - lse_ref[0, 0][:, None])
        do = do_ref[0, 0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v_ref[0, 0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        if dcap is not None:
            ds = ds * dcap
        ds = jnp.where(ok, ds, 0.0)
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ik == nkv - 1)
    def _finalize():
        dq_ref[0, 0] = acc_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                    softcap, q_offset, nq, group):
    ih = pl.program_id(1)
    ik = pl.program_id(2)
    ig = pl.program_id(3)   # inner loop over (q heads in group) x q blocks
    iq = ig % nq
    bq = q_ref.shape[2]
    bkv = k_ref.shape[2]
    del ih

    @pl.when(ig == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    needed = _block_needed(bq, bkv, iq, ik, causal=causal, window=window,
                           q_offset=q_offset)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        if softcap and softcap > 0.0:
            t = jnp.tanh(raw / softcap)
            logits = softcap * t
            dcap = 1.0 - t * t
        else:
            logits = raw
            dcap = None
        ok = _mask(bq, bkv, iq, ik, causal=causal, window=window,
                   q_offset=q_offset)
        logits = jnp.where(ok, logits, NEG_INF)
        p = jnp.exp(logits - lse_ref[0, 0][:, None])         # (bq, bkv)
        do = do_ref[0, 0].astype(jnp.float32)                # (bq, hd)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bkv, hd)
        dp = jax.lax.dot_general(do, v_ref[0, 0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        if dcap is not None:
            ds = ds * dcap
        ds = jnp.where(ok, ds, 0.0)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bkv, hd)

    total = nq * group

    @pl.when(ig == total - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal, window, scale,
                        softcap, q_offset, block_q, block_kv, interpret):
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bkv = min(block_kv, skv)
    while skv % bkv:
        bkv //= 2
    nq, nkv = sq // bq, skv // bkv

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)
    # delta = rowsum(do * out) per (b, h, s)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)   # (B, H, Sq)

    dq_kernel = functools.partial(
        _bwd_dq_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, nkv=nkv)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # dk/dv: grid over kv blocks; inner dim walks (group*nq) q tiles
    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, nq=nq, group=g)

    def qmap(ib, ih, ik, ig, g=g, nq=nq):
        return (ib, ih * g + ig // nq, ig % nq, 0)

    def lmap(ib, ih, ik, ig, g=g, nq=nq):
        return (ib, ih * g + ig // nq, ig % nq)

    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(b, hkv, nkv, g * nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), qmap),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, ig: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, ig: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bq, hd), qmap),
            pl.BlockSpec((1, 1, bq), lmap),
            pl.BlockSpec((1, 1, bq), lmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, ig: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda ib, ih, ik, ig: (ib, ih, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, skv, hd), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, skv, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bkv, hd), jnp.float32),
                        pltpu.VMEM((bkv, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    return (dq.transpose(0, 2, 1, 3), dk.transpose(0, 2, 1, 3),
            dv.transpose(0, 2, 1, 3))
