"""Pure-jnp oracle for the flash attention kernel (GQA + causal + local
window + softcap). Materialises the full (Sq, Skv) logits — only usable at
test scale, which is exactly its job."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0, scale: float | None = None,
                  softcap: float = 0.0, q_offset: int = 0) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd); H % Hkv == 0.

    ``q_offset`` is the absolute position of q[0] (decode/continuation)."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if scale is None:
        scale = 1.0 / float(hd) ** 0.5
    qg = q.reshape(b, sq, hkv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap and softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd).astype(q.dtype)
