"""Reproducible computation-environment setup (the serving front door).

Tests, benches and examples that need a *multi-device* mesh on a CPU-only
host call :func:`setup_devices` before anything touches the jax backend:

    from repro.configs import setup_devices
    setup_devices(platform="cpu", n_devices=8)

which forces XLA to expose ``n_devices`` host devices (the
``--xla_force_host_platform_device_count`` idiom), pins the platform and
optionally flips fp64 on — so a laptop and CI lower the exact same
sharded decode program as an 8-chip slice. The call is idempotent for
the same arguments and fails loudly when the backend was already
initialised with a different device count (jax reads these knobs once).
"""

from __future__ import annotations

import os
from typing import Sequence

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def _backend_initialized() -> bool:
    import jax

    # jax caches backends on first use; util.clear_backends is best-effort
    # and version-dependent, so we only *detect* initialisation here.
    try:
        return jax._src.xla_bridge._backends != {}  # noqa: SLF001
    except Exception:
        return False


def setup_devices(platform: str = "cpu", n_devices: int | None = None,
                  use_x64: bool = False) -> list:
    """Configure platform / device count / precision, returning the devices.

    Must run before the first jax computation. ``n_devices`` only has an
    effect on the host (CPU) platform, where XLA is told to expose that
    many independent devices — the standard recipe for exercising real
    GSPMD partitioning in unit tests.
    """
    if platform == "cpu" and n_devices is not None:
        flags = os.environ.get("XLA_FLAGS", "")
        parts = [p for p in flags.split() if not p.startswith(_FORCE_FLAG)]
        parts.append(f"{_FORCE_FLAG}={int(n_devices)}")
        os.environ["XLA_FLAGS"] = " ".join(parts)

    import jax

    jax.config.update("jax_platform_name", platform)
    jax.config.update("jax_enable_x64", bool(use_x64) or
                      bool(int(os.getenv("JAX_ENABLE_X64", "0") or 0)))

    devices = jax.devices()
    if n_devices is not None and len(devices) != int(n_devices):
        raise RuntimeError(
            f"requested {n_devices} {platform} devices but the backend "
            f"exposes {len(devices)} — setup_devices() must be called "
            f"before jax initialises (import repro.configs first, or set "
            f"XLA_FLAGS={_FORCE_FLAG}={n_devices} in the environment)")
    return devices


def make_serving_mesh(data: int = 1, model: int = 1,
                      axis_names: Sequence[str] = ("data", "model")):
    """Mesh over the forced host devices for sharded serving tests."""
    import jax

    return jax.make_mesh((int(data), int(model)), tuple(axis_names))
