"""Assigned architecture configs. ``get_config('<arch-id>')`` accepts the
public ids with dashes (e.g. ``deepseek-67b``)."""

from __future__ import annotations

import importlib

from repro.configs.devices import make_serving_mesh, setup_devices  # noqa: F401
from repro.models.common import ModelConfig

ARCH_IDS = [
    "deepseek-67b",
    "qwen3-4b",
    "granite-3-2b",
    "qwen2-0.5b",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "recurrentgemma-2b",
    "llava-next-34b",
    "whisper-large-v3",
    "xlstm-350m",
    # the paper's own demo config (small LM used by examples/)
    "aiida-demo-110m",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests (same family/topology, tiny sizes)
# ---------------------------------------------------------------------------

def reduced_config(arch_id: str) -> ModelConfig:
    cfg = get_config(arch_id)
    kw: dict = dict(
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        num_layers=2,
        attn_impl="direct",
        kv_repeat=1,
        moe_group_size=64,
        mlstm_chunk=32,
    )
    if cfg.family == "moe":
        kw.update(num_experts=4, num_experts_per_tok=2)
    if cfg.family == "hybrid":
        kw.update(num_layers=3, d_rnn=128, local_window=32)
    if cfg.family == "ssm":
        # keep >= 8 layers so at least one sLSTM position exists
        kw.update(num_layers=8, num_kv_heads=4, d_ff=0)
    if cfg.family == "audio":
        kw.update(num_kv_heads=4, encoder_layers=2, num_frames=16)
    if cfg.family == "vlm":
        kw.update(num_patches=8)
    if cfg.name == "xlstm-350m":
        kw["head_dim"] = 0
    return cfg.replace(**kw)
