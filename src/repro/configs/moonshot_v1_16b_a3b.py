"""Moonlight-16B-A3B (kimi/moonshot) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]. Expert-parallel: 64/16 = 4
experts per model-axis shard."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_experts_per_tok=6,
    rope_theta=50_000.0,
    mlp_act="silu",
    attn_impl="chunked",
    attn_sharding="heads",
    kv_repeat=1,
    moe_sharding="expert",
)
