"""Qwen2-0.5B — dense GQA with QKV bias [arXiv:2407.10671; hf].

14 heads do not divide a 16-way model axis -> sequence-parallel attention.
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    attn_impl="chunked",
    attn_sharding="sequence",
    kv_repeat=1,
)
