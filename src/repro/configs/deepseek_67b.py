"""DeepSeek-67B — dense llama-arch [arXiv:2401.02954; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10_000.0,
    mlp_act="silu",
    attn_impl="chunked",
    attn_sharding="heads",
    kv_repeat=2,            # 8 KV heads -> 16 for the 16-way model axis
)
