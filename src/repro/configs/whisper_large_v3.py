"""Whisper-large-v3 backbone — enc-dec, conv frontend STUBbed with
precomputed frame embeddings (B, 1500, d_model) [arXiv:2212.04356;
unverified]. 20 heads do not divide the 16-way model axis ->
sequence-parallel attention. Vocab 51866 padded to a multiple of 256."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,             # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    num_frames=1500,
    qkv_bias=True,
    use_rope=False,
    tie_embeddings=True,
    norm_eps=1e-5,
    mlp_act="gelu",
    attn_impl="chunked",
    attn_sharding="sequence",
    kv_repeat=1,
)
