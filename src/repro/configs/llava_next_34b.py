"""LLaVA-NeXT-34B backbone — anyres tiling frontend is a STUB:
input_specs() provides precomputed patch embeddings (B, 2880, d_model)
[hf:llava-hf; unverified]. 56 heads do not divide the 16-way model axis
-> sequence-parallel attention."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    num_patches=2880,          # 5 anyres tiles x 576 patches
    rope_theta=5_000_000.0,
    mlp_act="silu",
    attn_impl="chunked",
    attn_sharding="sequence",
    kv_repeat=1,
)
