"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, pattern 1:2
[arXiv:2402.19427; hf]. Sub-quadratic -> runs the long_500k cell.

10 heads do not divide the 16-way model axis -> sequence-parallel
attention; the RG-LRU width (2560) is TP-sharded."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    d_rnn=2560,
    conv_width=4,
    rope_theta=10_000.0,
    mlp_act="gelu",
    attn_impl="chunked",
    attn_sharding="sequence",
    kv_repeat=1,
)
