"""xLSTM-350M — sLSTM + mLSTM blocks, ratio 7:1 [arXiv:2405.04517;
unverified]. d_ff = 0: blocks carry internal projections, no separate FFN.
Linear recurrence -> runs the long_500k cell. 4 heads do not divide the
model axis -> inner/head-dim TP sharding."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlstm_proj_factor=2.0,
    slstm_conv_width=4,
    mlstm_chunk=128,
    use_rope=False,
    mlp_act="gelu",
    attn_impl="direct",
    attn_sharding="sequence",
    kv_repeat=1,
)
