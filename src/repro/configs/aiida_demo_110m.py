"""The paper's own demo config: a ~110M-parameter dense LM used by the
end-to-end example workflows (examples/train_lm.py). Small enough to train
for a few hundred steps on modest hardware under the engine."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="aiida-demo-110m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    rope_theta=10_000.0,
    mlp_act="silu",
    attn_impl="direct",
    attn_sharding="heads",
    kv_repeat=1,
)
