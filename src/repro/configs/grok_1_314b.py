"""Grok-1 314B — MoE 8 experts top-2, attention logit softcap
[hf:xai-org/grok-1; unverified].

8 experts do not divide the 16-way model axis -> TP-in-expert sharding
(d_ff 32768 sharded 16-way inside each expert, experts replicated).
"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    attn_softcap=30.0,
    rope_theta=10_000.0,
    mlp_act="gelu",
    attn_impl="chunked",
    attn_sharding="heads",
    kv_repeat=2,
    moe_sharding="ffn",
)
