"""Granite-3.0-2B-base — dense GQA with granite scalar multipliers
[hf:ibm-granite/granite-3.0-2b-base; hf]. Vocab 49155 is padded to a
multiple of 256 for model-axis divisibility (masked logits)."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    rope_theta=10_000.0,
    mlp_act="silu",
    tie_embeddings=True,
    embedding_multiplier=12.0,
    residual_multiplier=0.22,
    attention_multiplier=0.015625,
    logits_scaling=8.0,
    attn_impl="chunked",
    attn_sharding="heads",
    kv_repeat=2,
)
