"""Qwen3-4B — dense, qk-norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    attn_impl="chunked",
    attn_sharding="heads",
    kv_repeat=2,
)
