"""Process-wide metrics registry (counters, gauges, histograms — no deps).

One registry per python process absorbs what used to be scattered ad-hoc
``stats`` dicts (``scheduler.stats``, ``transport.stats``,
``store.stats``) behind a single queryable API:

* :class:`Counter` / :class:`Gauge` / :class:`Histogram` are the three
  primitive instruments; histograms use fixed bucket boundaries so a
  snapshot is a plain JSON document that merges across OS processes by
  summation.
* :class:`StatsDict` is the back-compat bridge: a real ``dict`` subclass
  (so ``stats["commits"] += 1`` and ``stats.get("commits")`` keep working
  unchanged in the hot paths and in ``store_bench --smoke``) that
  registers itself with the registry so its live values appear in
  ``registry().snapshot()`` under a prefix (``store.commits``, …).
* :func:`merge_snapshots` combines snapshots from many producers (e.g.
  every daemon worker's advertisement) into one merged view for
  ``repro stats``.

Incrementing a counter is one attribute add — cheap enough for hot paths
without any enable/disable gate.
"""

from __future__ import annotations

import threading
import weakref
from bisect import bisect_right
from typing import Any, Iterable, Mapping

#: default latency buckets (seconds) — spans sub-ms store commits up to
#: multi-second scheduler waits
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0)


class Counter:
    """Monotonically increasing integer."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A value that can go up and down (slots in use, queue depth …)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram: counts per upper bound + a +Inf overflow
    bucket, plus running sum/count for mean latency."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * (len(self.bounds) + 1)  # last = overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_right(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        return {"buckets": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class StatsDict(dict):
    """A plain dict of integer counters that is *also* visible to the
    metrics registry under ``<prefix>.<key>``. Existing call sites keep
    their ``stats["x"] += 1`` idiom (and ``isinstance(stats, dict)``
    checks) unchanged; the registry reads the live values at snapshot
    time, summing across instances that share a prefix (e.g. several
    open stores in one process)."""

    # identity hash: dict subclasses are unhashable by default, but the
    # registry's WeakSet needs to hold (weak) references to instances
    __hash__ = object.__hash__

    def __init__(self, prefix: str, initial: Mapping[str, int] | None = None,
                 registry: "MetricsRegistry | None" = None):
        super().__init__(initial or {})
        self.prefix = prefix
        (registry or get_registry())._register_stats(self)


class MetricsRegistry:
    """Create-or-get named instruments + snapshotting."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        # weak: a StatsDict dies with its owning store/scheduler/transport
        self._stats_producers: "weakref.WeakSet[StatsDict]" = weakref.WeakSet()

    # -- instruments --------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(buckets))
        return h

    def _register_stats(self, stats: StatsDict) -> None:
        self._stats_producers.add(stats)

    # -- snapshotting -------------------------------------------------------
    def snapshot(self) -> dict:
        """A JSON-able view of every instrument, with StatsDict producers
        folded in as ``<prefix>.<key>`` counters (summed per name)."""
        counters: dict[str, int] = {
            name: c.value for name, c in sorted(self._counters.items())}
        for stats in list(self._stats_producers):
            for key, val in stats.items():
                name = f"{stats.prefix}.{key}"
                counters[name] = counters.get(name, 0) + int(val)
        return {
            "counters": dict(sorted(counters.items())),
            "gauges": {name: g.value
                       for name, g in sorted(self._gauges.items())},
            "histograms": {name: h.to_dict()
                           for name, h in sorted(self._histograms.items())},
        }


def merge_snapshots(snapshots: Iterable[Mapping[str, Any]]) -> dict:
    """Merge many ``snapshot()`` documents (e.g. one per daemon worker):
    counters and histogram counts sum; gauges keep the last value seen."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for snap in snapshots:
        if not isinstance(snap, Mapping):
            continue
        for name, v in (snap.get("counters") or {}).items():
            out["counters"][name] = out["counters"].get(name, 0) + v
        for name, v in (snap.get("gauges") or {}).items():
            out["gauges"][name] = v
        for name, h in (snap.get("histograms") or {}).items():
            acc = out["histograms"].get(name)
            if acc is None or acc.get("buckets") != h.get("buckets"):
                out["histograms"][name] = {
                    "buckets": list(h.get("buckets", [])),
                    "counts": list(h.get("counts", [])),
                    "sum": h.get("sum", 0.0), "count": h.get("count", 0)}
            else:
                acc["counts"] = [a + b for a, b in
                                 zip(acc["counts"], h.get("counts", []))]
                acc["sum"] += h.get("sum", 0.0)
                acc["count"] += h.get("count", 0)
    out["counters"] = dict(sorted(out["counters"].items()))
    return out


# ---------------------------------------------------------------------------
# The process-wide registry
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def reset_registry() -> MetricsRegistry:
    """Swap in a fresh registry (test/benchmark isolation). StatsDict
    producers created against the old registry keep working as plain
    dicts; new ones register here."""
    global _REGISTRY
    _REGISTRY = MetricsRegistry()
    return _REGISTRY
