"""Persisted process timelines: span storage + wall-clock rendering.

A finished process's spans are serialized into ONE log row (levelname
``TRACE``) written inside the process's terminal store transaction — the
timeline rides the existing unit of work, so the ~2-commits-per-process
budget (asserted by ``store_bench --smoke``) is unchanged, and archives
carry timelines for free because log rows already travel.

``repro process report <pk>`` renders two views from here:

* the **span timeline** — an indented tree with per-span bars positioned
  on the process's wall clock (where did the time go?);
* the **state dwell table** — per-state residence times computed from the
  ``state_history`` attribute every process now records at each state
  transition (and, for legacy rows without it, a ctime→mtime total), so
  duration information exists even for runs traced with ``REPRO_TRACE=0``.
"""

from __future__ import annotations

import json
from typing import Any, Mapping, Sequence

TRACE_LEVELNAME = "TRACE"
STATE_HISTORY_ATTR = "state_history"


# ---------------------------------------------------------------------------
# Persistence (the TRACE log row)
# ---------------------------------------------------------------------------

def serialize_spans(spans: Sequence[Mapping[str, Any]]) -> str:
    """Normalize drained span dicts to a compact document: starts become
    offsets (seconds) from the earliest span, so the perf-counter origin
    never leaks out of the producing OS process."""
    if not spans:
        return json.dumps({"v": 1, "spans": []})
    t0 = min(s["start"] for s in spans)
    norm = []
    for s in spans:
        d = {"name": s["name"], "id": s["id"], "parent": s.get("parent"),
             "start": round(s["start"] - t0, 6),
             "dur": round(max(0.0, s["end"] - s["start"]), 6)}
        if s.get("attrs"):
            d["attrs"] = s["attrs"]
        norm.append(d)
    return json.dumps({"v": 1, "spans": norm}, separators=(",", ":"))


def load_spans(store, pk: int) -> list[dict]:
    """The persisted timeline of a process (last TRACE row wins), as
    normalized span dicts; [] when the process was never traced."""
    doc = None
    for log in store.get_logs(pk):
        if log["levelname"] == TRACE_LEVELNAME:
            doc = log["message"]
    if doc is None:
        return []
    try:
        return json.loads(doc).get("spans", [])
    except (ValueError, AttributeError):
        return []


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def _fmt_dur(seconds: float) -> str:
    if seconds < 0.001:
        return f"{seconds * 1e6:.0f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.1f}ms"
    return f"{seconds:.2f}s"


def render_timeline(spans: Sequence[Mapping[str, Any]],
                    width: int = 30) -> str:
    """ASCII tree of spans with bars on the process's wall clock."""
    if not spans:
        return "(no spans recorded — run with REPRO_TRACE=1)"
    total = max(s["start"] + s["dur"] for s in spans) or 1e-9
    children: dict[Any, list[dict]] = {}
    ids = {s["id"] for s in spans}
    roots: list[dict] = []
    for s in sorted(spans, key=lambda s: (s["start"], s["id"])):
        parent = s.get("parent")
        if parent in ids:
            children.setdefault(parent, []).append(s)
        else:
            roots.append(s)
    lines: list[str] = []

    def emit(s: Mapping[str, Any], depth: int) -> None:
        label = ("  " * depth + s["name"])[:38]
        lo = int(s["start"] / total * width)
        hi = max(lo + 1, int((s["start"] + s["dur"]) / total * width))
        bar = " " * lo + "#" * (hi - lo) + " " * (width - hi)
        lines.append(f"  {label:38} {_fmt_dur(s['dur']):>8} |{bar}|")
        for c in children.get(s["id"], []):
            emit(c, depth + 1)

    for root in roots:
        emit(root, 0)
    lines.append(f"  {'total':38} {_fmt_dur(total):>8}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# State dwell times
# ---------------------------------------------------------------------------

def state_dwell(node: Mapping[str, Any]) -> list[tuple[str, float]]:
    """Per-state residence times for one node row, from its recorded
    ``state_history`` attribute ([state, wall-ts] pairs). Falls back to a
    single ctime→mtime total for legacy rows that predate the attribute.
    Repeated visits to a state (pause/play cycles) are summed."""
    try:
        attrs = node.get("attributes")
        if isinstance(attrs, str):
            attrs = json.loads(attrs or "{}")
        history = (attrs or {}).get(STATE_HISTORY_ATTR)
    except ValueError:
        history = None
    if not history:
        total = max(0.0, (node.get("mtime") or 0) - (node.get("ctime") or 0))
        state = node.get("process_state") or "?"
        return [(f"(total, ending {state})", total)]
    entries = [(str(s), float(ts)) for s, ts in history]
    # the first recorded transition closes the CREATED dwell
    if node.get("ctime") and entries and entries[0][1] > node["ctime"]:
        entries.insert(0, ("created", float(node["ctime"])))
    out: dict[str, float] = {}
    order: list[str] = []
    for i, (state, ts) in enumerate(entries):
        nxt = entries[i + 1][1] if i + 1 < len(entries) else ts
        if state not in out:
            order.append(state)
        out[state] = out.get(state, 0.0) + max(0.0, nxt - ts)
    return [(s, out[s]) for s in order]


def render_dwell(node: Mapping[str, Any]) -> str:
    rows = state_dwell(node)
    total = sum(d for _s, d in rows) or 1e-9
    lines = []
    for state, dur in rows:
        lines.append(f"  {state:24} {_fmt_dur(dur):>8}  "
                     f"{dur / total * 100:5.1f}%")
    return "\n".join(lines)
