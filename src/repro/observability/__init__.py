"""Unified observability layer: span tracing, metrics, logs, timelines.

The profiling substrate for the engine (docs/observability.md):

* :mod:`repro.observability.trace` — spans with contextvar parent
  propagation; off by default (``REPRO_TRACE``), near-zero-cost when off.
* :mod:`repro.observability.metrics` — process-wide counter / gauge /
  histogram registry; :class:`~repro.observability.metrics.StatsDict`
  bridges the legacy ``*.stats`` dicts into it.
* :mod:`repro.observability.logs` — namespaced logging config honouring
  ``REPRO_LOG_LEVEL``, with worker-id + pk record tagging.
* :mod:`repro.observability.timeline` — persisted per-process span
  timelines + the renderers behind ``repro process report``.
"""

from repro.observability import logs, metrics, timeline, trace  # noqa: F401
from repro.observability.metrics import (  # noqa: F401
    StatsDict, get_registry, merge_snapshots,
)
from repro.observability.trace import span, traced  # noqa: F401
