"""Lightweight span tracer with contextvar-propagated parent ids.

A *span* is a named wall-clock interval with a parent — together they
form the per-process timeline that ``repro process report`` renders.
The API is a context manager (``with span("store.commit"):``) or a
decorator (``@traced("engine.submit")``); parent linkage flows through a
:mod:`contextvars` variable, so spans opened inside ``asyncio`` tasks
attach to the span that was current when the task was created, exactly
like ``CURRENT_PROCESS`` does for provenance CALL links.

Tracing is **off by default** (``REPRO_TRACE=0``) and the disabled path
is near-zero-cost: ``span()`` returns a shared no-op singleton — no
``Span`` object, no contextvar writes, no clock reads — so hot paths
(store commits, checkpoint flushes) can stay instrumented permanently.
``REPRO_TRACE_SAMPLE`` (0.0–1.0) keeps only that fraction of *root*
spans/timelines when tracing is on.

Finished spans go to the current :class:`Timeline` sink (set by
``Process.step_until_terminated`` for the duration of a run) or, when no
sink is active, to a small bounded in-memory ring for inspection.
"""

from __future__ import annotations

import contextvars
import functools
import inspect
import itertools
import os
import random
import time
from collections import deque
from typing import Any, Callable

ENV_VAR = "REPRO_TRACE"
SAMPLE_ENV_VAR = "REPRO_TRACE_SAMPLE"

_ids = itertools.count(1)

#: the innermost open span in this context (parent of any new span)
_CURRENT: contextvars.ContextVar["Span | None"] = \
    contextvars.ContextVar("TRACE_CURRENT", default=None)
#: where finished spans are collected (a per-process Timeline, usually)
_SINK: contextvars.ContextVar["Timeline | None"] = \
    contextvars.ContextVar("TRACE_SINK", default=None)

#: fallback ring for spans finished outside any timeline
_RECENT: deque = deque(maxlen=1000)

_enabled: bool | None = None  # None = not yet resolved from the env
_sample: float = 1.0


def _resolve() -> bool:
    global _enabled, _sample
    if _enabled is None:
        _enabled = os.environ.get(ENV_VAR, "0").lower() not in (
            "0", "", "false", "off", "no")
        try:
            _sample = min(1.0, max(0.0, float(
                os.environ.get(SAMPLE_ENV_VAR, "1.0"))))
        except ValueError:
            _sample = 1.0
    return _enabled


def enabled() -> bool:
    return _enabled if _enabled is not None else _resolve()


def enable(sample: float = 1.0) -> None:
    """Turn tracing on programmatically (overrides the env)."""
    global _enabled, _sample
    _enabled = True
    _sample = min(1.0, max(0.0, sample))


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Back to env-resolved state; clears the in-memory ring (tests)."""
    global _enabled
    _enabled = None
    _RECENT.clear()


def _sampled() -> bool:
    return _sample >= 1.0 or random.random() < _sample


class Span:
    """One named wall-clock interval. Use via :func:`span`, not directly."""

    __slots__ = ("name", "span_id", "parent", "start", "end", "attrs",
                 "_token")

    def __init__(self, name: str, attrs: dict | None):
        self.name = name
        self.span_id = next(_ids)
        self.attrs = attrs
        self.start = 0.0
        self.end = 0.0
        self.parent = _CURRENT.get()

    @property
    def parent_id(self) -> int | None:
        return self.parent.span_id if self.parent is not None else None

    def __enter__(self) -> "Span":
        self._token = _CURRENT.set(self)
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.end = time.perf_counter()
        _CURRENT.reset(self._token)
        sink = _SINK.get()
        if sink is not None:
            sink.append(self)
        else:
            _RECENT.append(self)

    def to_dict(self) -> dict:
        d = {"name": self.name, "id": self.span_id,
             "parent": self.parent_id, "start": self.start,
             "end": self.end}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _NoopSpan:
    """Shared do-nothing context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _NoopSpan()


def span(name: str, **attrs: Any):
    """Open a span (context manager). Returns the shared no-op singleton
    when tracing is disabled or this would-be root span is sampled out."""
    if not (_enabled if _enabled is not None else _resolve()):
        return _NOOP
    if _sample < 1.0 and _CURRENT.get() is None and not _sampled():
        return _NOOP
    return Span(name, attrs or None)


def traced(name: str | None = None, **attrs: Any) -> Callable:
    """Decorator form of :func:`span`; works on sync and async callables."""

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__
        if inspect.iscoroutinefunction(fn):
            @functools.wraps(fn)
            async def awrapper(*a, **kw):
                with span(label, **attrs):
                    return await fn(*a, **kw)
            return awrapper

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            with span(label, **attrs):
                return fn(*a, **kw)
        return wrapper

    return deco


def current_span() -> Span | None:
    return _CURRENT.get()


def recent_spans() -> list[Span]:
    """Spans finished outside any timeline (newest last)."""
    return list(_RECENT)


# ---------------------------------------------------------------------------
# Timelines — per-process span collection
# ---------------------------------------------------------------------------

class Timeline:
    """Collects the finished spans of one logical operation (a process
    run). Installed as the context's sink with :func:`push_sink`;
    drained once at the end — appends after draining are dropped so a
    late-finishing stray span cannot resurrect a persisted timeline."""

    __slots__ = ("spans", "_closed")

    def __init__(self) -> None:
        self.spans: list[Span] = []
        self._closed = False

    def append(self, s: Span) -> None:
        if not self._closed:
            self.spans.append(s)

    def drain(self, stamp_open: bool = True) -> list[dict]:
        """Close the timeline and return span dicts (chronological by
        start). With ``stamp_open``, spans still on the context stack
        (e.g. the root span around the caller) are included with their
        end stamped 'now'."""
        self._closed = True
        out = [s.to_dict() for s in self.spans]
        if stamp_open:
            now = time.perf_counter()
            open_span = _CURRENT.get()
            while open_span is not None:
                d = open_span.to_dict()
                d["end"] = now
                out.append(d)
                open_span = open_span.parent
        out.sort(key=lambda d: d["start"])
        return out


def start_timeline() -> Timeline | None:
    """A new sink for one process run — None when tracing is disabled or
    the run is sampled out (callers skip all timeline work then)."""
    if not (_enabled if _enabled is not None else _resolve()):
        return None
    if _sample < 1.0 and not _sampled():
        return None
    return Timeline()


def push_sink(sink: Timeline | None) -> contextvars.Token:
    return _SINK.set(sink)


def pop_sink(token: contextvars.Token) -> None:
    _SINK.reset(token)


class capture:
    """Context manager collecting every span finished inside the block —
    the test/benchmark harness: ``with capture() as spans: …``."""

    def __init__(self) -> None:
        self.timeline = Timeline()

    def __enter__(self) -> Timeline:
        self._token = push_sink(self.timeline)
        return self.timeline

    def __exit__(self, *exc) -> None:
        pop_sink(self._token)
