"""Namespaced logging configuration for daemon workers and the CLI.

``logging.basicConfig`` (what the daemon entry points used to call)
mutates the *root* logger — clobbering whatever configuration a host
application already installed. :func:`configure` instead attaches one
handler to the ``repro`` logger namespace only, honours
``REPRO_LOG_LEVEL`` (or an explicit ``level=``/``--log-level``), and is
idempotent: calling it again just re-applies the level.

Worker records are tagged with the worker id and — while a daemon worker
is driving a process — the pk of that process, via a contextvar that the
task handler sets around each run:

    12:03:55 WARNING repro.engine [worker.4711-ab12ef pk=42] ...
"""

from __future__ import annotations

import contextlib
import contextvars
import logging
import os
import sys
from typing import IO, Iterator

ENV_VAR = "REPRO_LOG_LEVEL"

#: the pk of the process the current context is driving (daemon workers)
CURRENT_PK: contextvars.ContextVar[int | None] = \
    contextvars.ContextVar("LOG_PK", default=None)

_worker_id: str | None = None


def set_worker_id(worker_id: str | None) -> None:
    """Tag every subsequent record from this OS process."""
    global _worker_id
    _worker_id = worker_id


@contextlib.contextmanager
def pk_context(pk: int) -> Iterator[None]:
    """Records emitted inside the block carry ``pk=<pk>``."""
    token = CURRENT_PK.set(pk)
    try:
        yield
    finally:
        CURRENT_PK.reset(token)


class _ContextFilter(logging.Filter):
    """Injects the ``ctx`` field ('[worker pk=N]') into each record."""

    def filter(self, record: logging.LogRecord) -> bool:
        parts = []
        if _worker_id is not None:
            parts.append(_worker_id)
        pk = CURRENT_PK.get()
        if pk is not None:
            parts.append(f"pk={pk}")
        record.ctx = f" [{' '.join(parts)}]" if parts else ""
        return True


def _resolve_level(level: int | str | None) -> int:
    if level is None:
        level = os.environ.get(ENV_VAR) or "WARNING"
    if isinstance(level, str):
        resolved = logging.getLevelName(level.upper())
        if not isinstance(resolved, int):
            raise ValueError(f"unknown log level {level!r}")
        return resolved
    return level


def configure(level: int | str | None = None,
              worker_id: str | None = None,
              stream: IO | None = None) -> logging.Logger:
    """Configure the ``repro`` logger namespace (and nothing else).

    Precedence for the level: explicit ``level`` argument, then the
    ``REPRO_LOG_LEVEL`` environment variable, then WARNING. Repeated
    calls re-apply the level without stacking handlers."""
    logger = logging.getLogger("repro")
    logger.setLevel(_resolve_level(level))
    if worker_id is not None:
        set_worker_id(worker_id)
    for h in logger.handlers:
        if getattr(h, "_repro_obs", False):
            return logger  # already configured; level updated above
    handler = logging.StreamHandler(stream or sys.stderr)
    handler._repro_obs = True
    handler.addFilter(_ContextFilter())
    handler.setFormatter(logging.Formatter(
        "%(asctime)s %(levelname)s %(name)s%(ctx)s: %(message)s",
        datefmt="%H:%M:%S"))
    logger.addHandler(handler)
    # our handler owns repro.* output; never double-print through root
    logger.propagate = False
    return logger
