"""Decoder-only LM covering the dense, MoE and VLM families.

The layer stack is homogeneous and executed with ``jax.lax.scan`` over
parameters stacked along a leading ``layers`` dimension: the lowered HLO
contains a single layer body regardless of depth, which keeps 512-way GSPMD
compiles tractable and is the standard production pattern (MaxText et al.).

Remat (activation checkpointing) wraps the scanned body; the policy is a
config knob so the §Perf iterations can trade recompute for memory.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    ModelConfig,
    ParamSpec,
    maybe_remat,
    rms_norm,
    shard,
    softmax_cross_entropy,
    stack_specs,
)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def make_layer_specs(cfg: ModelConfig) -> dict[str, Any]:
    specs: dict[str, Any] = {
        "ln_attn": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln_mlp": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.make_attn_specs(cfg),
    }
    if cfg.family == "moe":
        specs["moe"] = mlp_mod.make_moe_specs(cfg)
    else:
        specs["mlp"] = mlp_mod.make_mlp_specs(cfg)
    return specs


def make_lm_specs(cfg: ModelConfig) -> dict[str, Any]:
    vp = cfg.padded_vocab
    specs: dict[str, Any] = {
        "embedding": ParamSpec((vp, cfg.d_model), ("vocab", "embed")),
        "layers": stack_specs(make_layer_specs(cfg), cfg.num_layers),
        "ln_final": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, vp), ("embed", "vocab"))
    if cfg.family == "vlm":
        specs["mm_projector"] = ParamSpec(
            (cfg.d_model, cfg.d_model), ("embed", "embed_out"))
    return specs


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _layer_forward(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                   positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pre-norm block. Returns (x, aux_loss)."""
    rm = cfg.residual_multiplier
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    a = attn.attn_forward(cfg, p["attn"], h, positions, causal=True)
    x = x + rm * a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        m, aux = mlp_mod.moe_forward(cfg, p["moe"], h)
    else:
        m = mlp_mod.mlp_forward(cfg, p["mlp"], h)
    x = x + rm * m
    x = shard(x, "batch", "act_seq", None)
    return x, aux


def _stack_forward(cfg: ModelConfig, params: dict[str, Any], x: jax.Array,
                   positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    def body(carry, layer_params):
        h, aux = carry
        h, a = _layer_forward(cfg, layer_params, h, positions)
        return (h, aux + a), None

    body = maybe_remat(body, cfg.remat_policy)
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll_layers:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            carry, _ = body(carry, lp)
        return carry
    (x, aux), _ = lax.scan(body, carry, params["layers"])
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: dict[str, Any], tokens: jax.Array
                 ) -> jax.Array:
    emb = params["embedding"].astype(cfg.activation_dtype)
    x = jnp.take(emb, tokens, axis=0)
    return x * cfg.embedding_multiplier


def lm_logits(cfg: ModelConfig, params: dict[str, Any], x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embedding"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = shard(logits, "batch", "act_seq", "vocab_sharded")
    if cfg.logits_scaling != 1.0:
        logits = logits / cfg.logits_scaling
    return logits


# ---------------------------------------------------------------------------
# Full forward / loss
# ---------------------------------------------------------------------------

def _maybe_prepend_patches(cfg: ModelConfig, params: dict[str, Any],
                           x: jax.Array, batch: dict[str, jax.Array]):
    """VLM family: prepend (projected) precomputed patch embeddings (stub)."""
    if cfg.family != "vlm":
        return x
    patches = batch["patches"].astype(x.dtype)          # (B, P, D) stub
    proj = jnp.einsum("bpd,de->bpe", patches,
                      params["mm_projector"].astype(x.dtype))
    return jnp.concatenate([proj, x], axis=1)


def lm_forward(cfg: ModelConfig, params: dict[str, Any],
               batch: dict[str, jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Returns (logits over the text region, aux_loss)."""
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    x = _maybe_prepend_patches(cfg, params, x, batch)
    x = shard(x, "batch", "act_seq", None)
    s_total = x.shape[1]
    positions = jnp.arange(s_total, dtype=jnp.int32)
    x, aux = _stack_forward(cfg, params, x, positions)
    if cfg.family == "vlm":
        x = x[:, cfg.num_patches:]                       # loss on text only
    logits = lm_logits(cfg, params, x)
    return logits, aux


def _chunked_ce(cfg: ModelConfig, params: dict[str, Any], x: jax.Array,
                labels: jax.Array, mask: jax.Array | None
                ) -> tuple[jax.Array, jax.Array]:
    """Streamed CE: logits are computed per sequence chunk under remat so
    the (B, S, Vp) fp32 tensor never exists — a large live-memory and
    bytes-accessed win for big-vocab models."""
    b, s, d = x.shape
    c = min(cfg.ce_chunk, s)
    while s % c:
        c //= 2
    n = s // c
    xc = x.reshape(b, n, c, d).swapaxes(0, 1)           # (n, B, c, D)
    lc = labels.reshape(b, n, c).swapaxes(0, 1)
    mc = (mask.reshape(b, n, c).swapaxes(0, 1)
          if mask is not None else None)

    def chunk_loss(args):
        xi, li, mi = args
        logits = lm_logits(cfg, params, xi)
        loss, denom = softmax_cross_entropy(logits, li, mi, cfg.vocab_size)
        return loss * denom, denom                       # un-normalised sum

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(carry, args):
        tot, den = carry
        ls, dn = chunk_loss(args)
        return (tot + ls, den + dn), None

    ms = mc if mc is not None else jnp.ones((n, b, c), jnp.float32)
    (tot, den), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)),
                             (xc, lc, ms))
    return tot / jnp.maximum(den, 1.0), den


def lm_loss(cfg: ModelConfig, params: dict[str, Any],
            batch: dict[str, jax.Array]) -> tuple[jax.Array, dict[str, jax.Array]]:
    labels = batch["labels"]
    mask = batch.get("mask")
    if cfg.ce_chunk:
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens)
        x = _maybe_prepend_patches(cfg, params, x, batch)
        x = shard(x, "batch", "act_seq", None)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x, aux = _stack_forward(cfg, params, x, positions)
        if cfg.family == "vlm":
            x = x[:, cfg.num_patches:]
        loss, denom = _chunked_ce(cfg, params, x, labels, mask)
    else:
        logits, aux = lm_forward(cfg, params, batch)
        loss, denom = softmax_cross_entropy(logits, labels, mask,
                                            cfg.vocab_size)
    total = loss + 0.01 * aux
    return total, {"ce_loss": loss, "aux_loss": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict[str, Any]:
    return attn.init_kv_cache(cfg, batch, max_len, layers=cfg.num_layers)


def lm_cache_axes(cfg: ModelConfig) -> dict[str, Any]:
    return attn.kv_cache_axes(cfg, layers=True)


def lm_prefill(cfg: ModelConfig, params: dict[str, Any],
               batch: dict[str, jax.Array], cache: dict[str, Any]
               ) -> tuple[jax.Array, dict[str, Any]]:
    """Run the prompt through the stack, filling the cache.

    Returns (last-position logits, cache).
    """
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    x = _maybe_prepend_patches(cfg, params, x, batch)
    x = shard(x, "batch", "act_seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        layer_params, layer_cache = xs
        hn = rms_norm(h, layer_params["ln_attn"], cfg.norm_eps)
        a, new_cache = attn.prefill_into_cache(
            cfg, layer_params["attn"], hn, positions, layer_cache)
        h = h + cfg.residual_multiplier * a
        hn = rms_norm(h, layer_params["ln_mlp"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = mlp_mod.moe_forward(cfg, layer_params["moe"], hn)
        else:
            m = mlp_mod.mlp_forward(cfg, layer_params["mlp"], hn)
        h = h + cfg.residual_multiplier * m
        h = shard(h, "batch", "act_seq", None)
        return h, new_cache

    body = maybe_remat(body, cfg.remat_policy)
    if cfg.unroll_layers:
        new_layers = []
        for i in range(cfg.num_layers):
            xs = jax.tree.map(lambda t: t[i], (params["layers"], cache))
            x, nc = body(x, xs)
            new_layers.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *new_layers)
    else:
        x, new_cache = lax.scan(body, x, (params["layers"], cache))
    logits = lm_logits(cfg, params, x[:, -1:])
    return logits, new_cache


def lm_decode_step(cfg: ModelConfig, params: dict[str, Any],
                   cache: dict[str, Any], tokens: jax.Array, pos: jax.Array
                   ) -> tuple[jax.Array, dict[str, Any]]:
    """One decode step. tokens: (B, 1); pos: scalar current position."""
    x = embed_tokens(cfg, params, tokens)
    x = shard(x, "batch", None, None)

    def body(h, xs):
        layer_params, layer_cache = xs
        hn = rms_norm(h, layer_params["ln_attn"], cfg.norm_eps)
        a, new_cache = attn.attn_decode(cfg, layer_params["attn"], hn,
                                        layer_cache, pos)
        h = h + cfg.residual_multiplier * a
        hn = rms_norm(h, layer_params["ln_mlp"], cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = mlp_mod.moe_forward(cfg, layer_params["moe"], hn)
        else:
            m = mlp_mod.mlp_forward(cfg, layer_params["mlp"], hn)
        h = h + cfg.residual_multiplier * m
        return h, new_cache

    if cfg.unroll_layers:
        new_layers = []
        for i in range(cfg.num_layers):
            xs = jax.tree.map(lambda t: t[i], (params["layers"], cache))
            x, nc = body(x, xs)
            new_layers.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *new_layers)
    else:
        x, new_cache = lax.scan(body, x, (params["layers"], cache))
    logits = lm_logits(cfg, params, x)
    return logits, new_cache
