"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (assignment: "RG-LRU + local attn, 1:2") is the Griffin
``(recurrent, recurrent, local-attention)`` repeating unit. The 26-layer
stack is *unrolled* (heterogeneous blocks; the model is small so compile cost
is negligible next to the scanned 95-layer stacks).

The RG-LRU recurrence ``h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)``
is evaluated blockwise: a sequential ``lax.scan`` over time blocks with an
``associative_scan`` inside each block — the exact structure the Pallas
kernel (kernels/rglru_scan) implements on TPU, and sub-quadratic in sequence
length (this is why this arch runs the ``long_500k`` cell).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    ModelConfig,
    ParamSpec,
    maybe_remat,
    rms_norm,
    shard,
    softmax_cross_entropy,
)

RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def make_rglru_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, dr = cfg.d_model, cfg.d_rnn or cfg.d_model
    w = cfg.conv_width
    nb = cfg.rnn_blocks
    blk = dr // nb
    # Gates are block-diagonal (nb blocks) so the gate matmuls shard over the
    # model axis with zero communication. The official RecurrentGemma uses
    # num_heads(=10) diagonal blocks; we use 16 to align blocks with the
    # model-axis shards (noted in DESIGN.md §hardware adaptation).
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "w_y": ParamSpec((d, dr), ("embed", "rnn_tp")),        # gate branch
        "w_x": ParamSpec((d, dr), ("embed", "rnn_tp")),        # recurrence branch
        "conv_w": ParamSpec((w, dr), (None, "rnn_tp")),
        "conv_b": ParamSpec((dr,), ("rnn_tp",), init="zeros"),
        "w_a": ParamSpec((nb, blk, blk), ("rnn_blocks", None, None)),
        "b_a": ParamSpec((dr,), ("rnn_tp",), init="zeros"),
        "w_i": ParamSpec((nb, blk, blk), ("rnn_blocks", None, None)),
        "b_i": ParamSpec((dr,), ("rnn_tp",), init="zeros"),
        "lam": ParamSpec((dr,), ("rnn_tp",), init="rglru_lambda"),
        "w_o": ParamSpec((dr, d), ("rnn_tp", "embed")),
    }


def make_attn_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.make_attn_specs(cfg),
    }


def make_mlp_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mlp": mlp_mod.make_mlp_specs(cfg),
    }


def layer_kinds(cfg: ModelConfig) -> list[str]:
    pattern = cfg.block_pattern or ("rglru", "rglru", "attn")
    return [pattern[i % len(pattern)] for i in range(cfg.num_layers)]


def make_griffin_specs(cfg: ModelConfig) -> dict[str, Any]:
    layers = []
    for kind in layer_kinds(cfg):
        if kind == "rglru":
            layers.append({"kind_rglru": make_rglru_block_specs(cfg),
                           "mlp_block": make_mlp_block_specs(cfg)})
        else:
            layers.append({"kind_attn": make_attn_block_specs(cfg),
                           "mlp_block": make_mlp_block_specs(cfg)})
    return {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "layers": layers,
        "ln_final": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_gates(p: dict[str, jax.Array], xr: jax.Array):
    """Gate computation shared by scan paths. xr: (..., dr) post-conv input.

    Gates are block-diagonal: w_a/w_i have shape (nb, blk, blk)."""
    f32 = jnp.float32
    nb, blk, _ = p["w_a"].shape
    xb = xr.astype(f32).reshape(*xr.shape[:-1], nb, blk)
    ra = jnp.einsum("...bk,bko->...bo", xb, p["w_a"].astype(f32))
    ia = jnp.einsum("...bk,bko->...bo", xb, p["w_i"].astype(f32))
    ra = ra.reshape(xr.shape) + p["b_a"].astype(f32)
    ia = ia.reshape(xr.shape) + p["b_i"].astype(f32)
    r = jax.nn.sigmoid(ra)
    i = jax.nn.sigmoid(ia)
    log_a = -RG_LRU_C * r * jax.nn.softplus(p["lam"].astype(f32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated_x = i * xr.astype(f32)
    return a, beta * gated_x


def rglru_scan_ref(a: jax.Array, bx: jax.Array, h0: jax.Array,
                   block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blocked linear scan. a, bx: (B, S, dr) fp32; h0: (B, dr).

    Returns (h over all t, final h). Outer sequential scan over time blocks,
    inner associative_scan — mirrors the Pallas kernel structure.
    """
    b, s, dr = a.shape
    blk = min(block, s)
    while s % blk:
        blk //= 2
    n = s // blk
    a_b = a.reshape(b, n, blk, dr).swapaxes(0, 1)   # (n, B, blk, dr)
    x_b = bx.reshape(b, n, blk, dr).swapaxes(0, 1)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, x2 + a2 * x1

    def body(h, xs):
        ab, xb = xs
        a_acc, x_acc = lax.associative_scan(combine, (ab, xb), axis=1)
        hs = x_acc + a_acc * h[:, None, :]
        return hs[:, -1, :], hs

    h_last, hs = lax.scan(body, h0, (a_b, x_b))
    hs = hs.swapaxes(0, 1).reshape(b, s, dr)
    return hs, h_last


def _causal_conv(p: dict[str, jax.Array], x: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv over time. x: (B, S, dr); state: (B, w-1, dr)."""
    w = p["conv_w"].shape[0]
    dt = x.dtype
    if state is None:
        pad = jnp.zeros((x.shape[0], w - 1, x.shape[2]), dt)
    else:
        pad = state.astype(dt)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for j in range(w):
        out = out + xp[:, j:j + x.shape[1], :].astype(jnp.float32) * \
            p["conv_w"][j].astype(jnp.float32)
    out = out + p["conv_b"].astype(jnp.float32)
    new_state = xp[:, xp.shape[1] - (w - 1):, :]
    return out.astype(dt), new_state


def rglru_block_forward(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                        state: dict[str, jax.Array] | None = None,
                        use_pallas: bool = False):
    """Full-sequence recurrent block. Returns (out, new_state)."""
    dt = x.dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", h, p["w_y"].astype(dt)))
    xr = jnp.einsum("bsd,dr->bsr", h, p["w_x"].astype(dt))
    xr = shard(xr, "batch", "act_seq_rnn", "rnn_sharded")
    conv_state = state["conv"] if state is not None else None
    xr, new_conv = _causal_conv(p, xr, conv_state)
    a, bx = rglru_gates(p, xr)
    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((x.shape[0], a.shape[-1]), jnp.float32))
    if use_pallas:
        from repro.kernels.rglru_scan import ops as rg_ops
        hs, h_last = rg_ops.rglru_scan(a, bx, h0)
    else:
        hs, h_last = rglru_scan_ref(a, bx, h0)
    hs = hs.astype(dt) * y
    out = jnp.einsum("bsr,rd->bsd", hs, p["w_o"].astype(dt))
    new_state = {"h": h_last, "conv": new_conv}
    return out, new_state


def rglru_block_decode(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                       state: dict[str, jax.Array]):
    """Single-token step. x: (B, 1, D)."""
    out, new_state = rglru_block_forward(cfg, p, x, state)
    return out, new_state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _mlp_sub(cfg: ModelConfig, p: dict[str, Any], x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    return x + mlp_mod.mlp_forward(cfg, p["mlp"], h)


def griffin_forward(cfg: ModelConfig, params: dict[str, Any],
                    batch: dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype), tokens, axis=0)
    x = x * (cfg.d_model ** 0.5)      # gemma-style embedding scaling
    x = shard(x, "batch", "act_seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    kinds = layer_kinds(cfg)

    def layer(x, p, kind):
        if kind == "rglru":
            out, _ = rglru_block_forward(cfg, p["kind_rglru"], x,
                                         use_pallas=cfg.use_pallas)
            x = x + out
        else:
            h = rms_norm(x, p["kind_attn"]["ln"], cfg.norm_eps)
            x = x + attn.attn_forward(cfg, p["kind_attn"]["attn"], h, positions,
                                      causal=True, window=cfg.local_window)
        return _mlp_sub(cfg, p["mlp_block"], x)

    for i, (p, kind) in enumerate(zip(params["layers"], kinds)):
        fn = maybe_remat(lambda x, p, k=kind: (layer(x, p, k), None),
                         cfg.remat_policy)
        x, _ = fn(x, p)
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    emb = params["embedding"].astype(x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x, emb)   # tied head
    return shard(logits, "batch", "act_seq", "vocab_sharded")


def griffin_loss(cfg: ModelConfig, params: dict[str, Any],
                 batch: dict[str, jax.Array]):
    logits = griffin_forward(cfg, params, batch)
    loss, denom = softmax_cross_entropy(
        logits, batch["labels"], batch.get("mask"), cfg.vocab_size)
    return loss, {"ce_loss": loss, "tokens": denom,
                  "aux_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_griffin_state(cfg: ModelConfig, batch: int, max_len: int) -> list[dict]:
    dr = cfg.d_rnn or cfg.d_model
    states: list[dict] = []
    for kind in layer_kinds(cfg):
        if kind == "rglru":
            states.append({
                "h": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, dr),
                                  cfg.activation_dtype),
            })
        else:
            w = min(cfg.local_window or max_len, max_len)
            states.append(attn.init_kv_cache(cfg, batch, w))
    return states


def griffin_state_axes(cfg: ModelConfig) -> list[dict]:
    axes: list[dict] = []
    for kind in layer_kinds(cfg):
        if kind == "rglru":
            axes.append({"h": ("batch", "rnn_sharded"),
                         "conv": ("batch", None, "rnn_sharded")})
        else:
            axes.append(attn.kv_cache_axes(cfg, layers=False))
    return axes


def griffin_prefill(cfg: ModelConfig, params: dict[str, Any],
                    batch: dict[str, jax.Array], states: list[dict]):
    tokens = batch["tokens"]
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype), tokens, axis=0)
    x = x * (cfg.d_model ** 0.5)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    kinds = layer_kinds(cfg)
    new_states: list[dict] = []
    for p, kind, st in zip(params["layers"], kinds, states):
        if kind == "rglru":
            out, ns = rglru_block_forward(cfg, p["kind_rglru"], x,
                                          use_pallas=cfg.use_pallas)
            x = x + out
        else:
            h = rms_norm(x, p["kind_attn"]["ln"], cfg.norm_eps)
            a, ns = attn.prefill_into_cache(cfg, p["kind_attn"]["attn"], h,
                                            positions, st,
                                            window=cfg.local_window)
            x = x + a
        x = _mlp_sub(cfg, p["mlp_block"], x)
        new_states.append(ns)
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:],
                        params["embedding"].astype(x.dtype))
    return logits, new_states


def griffin_decode_step(cfg: ModelConfig, params: dict[str, Any],
                        states: list[dict], tokens: jax.Array, pos: jax.Array):
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype), tokens, axis=0)
    x = x * (cfg.d_model ** 0.5)
    kinds = layer_kinds(cfg)
    new_states: list[dict] = []
    for p, kind, st in zip(params["layers"], kinds, states):
        if kind == "rglru":
            out, ns = rglru_block_decode(cfg, p["kind_rglru"], x, st)
            x = x + out
        else:
            h = rms_norm(x, p["kind_attn"]["ln"], cfg.norm_eps)
            a, ns = attn.attn_decode(cfg, p["kind_attn"]["attn"], h, st, pos,
                                     window=cfg.local_window)
            x = x + a
        x = _mlp_sub(cfg, p["mlp_block"], x)
        new_states.append(ns)
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    return logits, new_states
