"""Whisper-large-v3 transformer backbone (encoder-decoder).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, num_frames, d_model) as the
encoder input. The backbone is faithful otherwise: LayerNorm (with bias),
plain GELU MLPs (not gated), MHA with kv == heads, tied decoder embedding.
Position embeddings are sinusoidal for both stacks (whisper uses learned
decoder positions — swapped for table-free sinusoidal so one config serves
arbitrary assigned sequence lengths; noted in DESIGN.md).

Both stacks are homogeneous and scanned.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models.common import (
    ModelConfig,
    ParamSpec,
    layer_norm,
    maybe_remat,
    scan_or_unroll,
    shard,
    sinusoidal_positions,
    softmax_cross_entropy,
    stack_specs,
)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _ln_specs(d: int) -> dict[str, ParamSpec]:
    return {"w": ParamSpec((d,), ("embed",), init="ones"),
            "b": ParamSpec((d,), ("embed",), init="zeros")}


def _plain_mlp_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamSpec((d, f), ("embed", "ffn")),
        "b1": ParamSpec((f,), ("ffn",), init="zeros"),
        "w2": ParamSpec((f, d), ("ffn", "embed")),
        "b2": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _enc_layer_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "ln1": _ln_specs(cfg.d_model),
        "attn": attn.make_attn_specs(cfg),
        "ln2": _ln_specs(cfg.d_model),
        "mlp": _plain_mlp_specs(cfg),
    }


def _dec_layer_specs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "ln1": _ln_specs(cfg.d_model),
        "self_attn": attn.make_attn_specs(cfg),
        "ln2": _ln_specs(cfg.d_model),
        "cross_attn": attn.make_attn_specs(cfg, cross=True),
        "ln3": _ln_specs(cfg.d_model),
        "mlp": _plain_mlp_specs(cfg),
    }


def make_whisper_specs(cfg: ModelConfig) -> dict[str, Any]:
    enc_layers = cfg.encoder_layers or cfg.num_layers
    return {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                               ("vocab", "embed")),
        "enc_layers": stack_specs(_enc_layer_specs(cfg), enc_layers),
        "enc_ln": _ln_specs(cfg.d_model),
        "dec_layers": stack_specs(_dec_layer_specs(cfg), cfg.num_layers),
        "dec_ln": _ln_specs(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _mlp(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dt)) + p["b1"].astype(dt)
    h = jax.nn.gelu(h)
    h = shard(h, "batch", None, "ffn_sharded")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(dt)) + p["b2"].astype(dt)


def _ln(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    return layer_norm(x, p["w"], p["b"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: dict[str, Any], frames: jax.Array
           ) -> jax.Array:
    """frames: (B, T, D) precomputed frame embeddings (stub frontend)."""
    dt = cfg.activation_dtype
    x = frames.astype(dt)
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    x = x + pos[None]
    x = shard(x, "batch", "act_seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, p):
        a = attn.attn_forward(cfg, p["attn"], _ln(cfg, p["ln1"], h),
                              positions, causal=False)
        h = h + a
        h = h + _mlp(cfg, p["mlp"], _ln(cfg, p["ln2"], h))
        h = shard(h, "batch", "act_seq", None)
        return h, None

    body = maybe_remat(body, cfg.remat_policy)
    x, _ = scan_or_unroll(body, x, params["enc_layers"],
                          unroll=cfg.unroll_layers)
    return _ln(cfg, params["enc_ln"], x)


# ---------------------------------------------------------------------------
# Decoder (training / teacher-forced)
# ---------------------------------------------------------------------------

def decode_train(cfg: ModelConfig, params: dict[str, Any], tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    dt = cfg.activation_dtype
    x = jnp.take(params["embedding"].astype(dt), tokens, axis=0)
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    x = x + pos[None]
    x = shard(x, "batch", "act_seq", None)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(h, p):
        a = attn.attn_forward(cfg, p["self_attn"], _ln(cfg, p["ln1"], h),
                              positions, causal=True)
        h = h + a
        c = attn.attn_forward(cfg, p["cross_attn"], _ln(cfg, p["ln2"], h),
                              positions, causal=False, kv_x=enc_out,
                              kv_positions=enc_positions)
        h = h + c
        h = h + _mlp(cfg, p["mlp"], _ln(cfg, p["ln3"], h))
        h = shard(h, "batch", "act_seq", None)
        return h, None

    body = maybe_remat(body, cfg.remat_policy)
    x, _ = scan_or_unroll(body, x, params["dec_layers"],
                          unroll=cfg.unroll_layers)
    x = _ln(cfg, params["dec_ln"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(dt))
    return shard(logits, "batch", "act_seq", "vocab_sharded")


def whisper_loss(cfg: ModelConfig, params: dict[str, Any],
                 batch: dict[str, jax.Array]):
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc_out)
    loss, denom = softmax_cross_entropy(
        logits, batch["labels"], batch.get("mask"), cfg.vocab_size)
    return loss, {"ce_loss": loss, "tokens": denom,
                  "aux_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_whisper_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Self-attn KV cache + cross-attn KV (filled at prefill)."""
    enc_layers = cfg.encoder_layers or cfg.num_layers
    del enc_layers
    hkv, hd = cfg.kv_heads_eff, cfg.hd
    t = cfg.num_frames
    return {
        "self": attn.init_kv_cache(cfg, batch, max_len, layers=cfg.num_layers),
        "cross_k": jnp.zeros((cfg.num_layers, batch, t, hkv, hd),
                             cfg.activation_dtype),
        "cross_v": jnp.zeros((cfg.num_layers, batch, t, hkv, hd),
                             cfg.activation_dtype),
    }


def whisper_cache_axes(cfg: ModelConfig) -> dict:
    ca = ("layers", "kv_batch", "kv_seq_sharded", None, None)
    return {"self": attn.kv_cache_axes(cfg, layers=True),
            "cross_k": ca, "cross_v": ca}


def whisper_prefill(cfg: ModelConfig, params: dict[str, Any],
                    batch: dict[str, jax.Array], cache: dict):
    """Encode audio + run the teacher-forced prompt, filling both caches."""
    dt = cfg.activation_dtype
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    x = jnp.take(params["embedding"].astype(dt), tokens, axis=0)
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    x = x + pos[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, xs):
        p, self_cache = xs
        a, new_self = attn.prefill_into_cache(
            cfg, p["self_attn"], _ln(cfg, p["ln1"], h), positions, self_cache)
        h = h + a
        # cross attention + record enc K/V
        hq = _ln(cfg, p["ln2"], h)
        ck = jnp.einsum("btd,dhk->bthk", enc_out, p["cross_attn"]["wk"].astype(dt))
        cv = jnp.einsum("btd,dhk->bthk", enc_out, p["cross_attn"]["wv"].astype(dt))
        if cfg.qkv_bias:
            ck = ck + p["cross_attn"]["bk"].astype(dt)
            cv = cv + p["cross_attn"]["bv"].astype(dt)
        if cfg.kv_repeat > 1:
            ck = jnp.repeat(ck, cfg.kv_repeat, axis=2)
            cv = jnp.repeat(cv, cfg.kv_repeat, axis=2)
        c = attn.attn_forward(cfg, p["cross_attn"], hq, positions,
                              causal=False, kv_x=enc_out,
                              kv_positions=jnp.arange(enc_out.shape[1],
                                                      dtype=jnp.int32))
        h = h + c
        h = h + _mlp(cfg, p["mlp"], _ln(cfg, p["ln3"], h))
        return h, (new_self, ck, cv)

    x, (new_self, cross_k, cross_v) = scan_or_unroll(
        body, x, (params["dec_layers"], cache["self"]),
        unroll=cfg.unroll_layers)
    x = _ln(cfg, params["dec_ln"], x[:, -1:])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(dt))
    return logits, {"self": new_self, "cross_k": cross_k, "cross_v": cross_v}


def whisper_decode_step(cfg: ModelConfig, params: dict[str, Any], cache: dict,
                        tokens: jax.Array, pos: jax.Array):
    dt = cfg.activation_dtype
    x = jnp.take(params["embedding"].astype(dt), tokens, axis=0)
    posv = jnp.asarray(pos, jnp.int32)
    # sinusoidal position of the current step
    half = cfg.d_model // 2
    import math as _math
    log_ts = _math.log(10_000.0) / (half - 1)
    inv = jnp.exp(-log_ts * jnp.arange(half, dtype=jnp.float32))
    ang = posv.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :].astype(dt)
    x = x + pe

    b = x.shape[0]
    h_, hd = cfg.num_heads, cfg.hd

    def body(h, xs):
        p, self_cache, ck, cv = xs
        a, new_self = attn.attn_decode(cfg, p["self_attn"],
                                       _ln(cfg, p["ln1"], h), self_cache, pos)
        h = h + a
        hq = _ln(cfg, p["ln2"], h)
        q = jnp.einsum("bsd,dhk->bshk", hq, p["cross_attn"]["wq"].astype(dt))
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"].astype(dt)
        hkv = ck.shape[2]
        g = h_ // hkv
        qg = q.reshape(b, 1, hkv, g, hd)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, ck).astype(jnp.float32)
        logits = logits * (1.0 / float(hd) ** 0.5)
        probs = jax.nn.softmax(logits, axis=-1).astype(dt)
        o = jnp.einsum("bkgst,btkh->bskgh", probs, cv).reshape(b, 1, h_, hd)
        c = jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"].astype(dt))
        h = h + c
        h = h + _mlp(cfg, p["mlp"], _ln(cfg, p["ln3"], h))
        return h, new_self

    x, new_self = scan_or_unroll(
        body, x, (params["dec_layers"], cache["self"],
                  cache["cross_k"], cache["cross_v"]),
        unroll=cfg.unroll_layers)
    x = _ln(cfg, params["dec_ln"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(dt))
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
