"""Dense gated MLPs and Mixture-of-Experts layers.

The MoE layer uses the GShard/Switch grouped-einsum dispatch so it lowers to
clean ``all_to_all`` collectives under GSPMD:

* tokens are reshaped into groups of ``moe_group_size``;
* per group, each expert has capacity ``C = ceil(g·k/E · capacity_factor)``;
* dispatch/combine tensors are (G, g, E, C) one-hots — their memory is
  ``O(tokens · E · C / g)`` which stays modest for the group sizes used.

Two sharding strategies (resolved per architecture):

* ``expert`` (EP): the expert dim of the weights maps to the model axis
  (moonshot: 64 experts / 16). Dispatch einsums induce all_to_alls.
* ``ffn`` (TP-in-expert): experts replicated, each expert's d_ff sharded
  (grok: 8 experts do not divide a 16-way axis, but d_ff=32768 does).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import ModelConfig, ParamSpec, act_fn, shard


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def make_mlp_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn")),
        "w_up": ParamSpec((d, f), ("embed", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def mlp_forward(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    dt = x.dtype
    act = act_fn(cfg.mlp_act)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    h = act(g) * u
    h = shard(h, "batch", None, "ffn_sharded")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def make_moe_specs(cfg: ModelConfig) -> dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    if cfg.moe_sharding == "expert":
        # EP: the expert dim takes the model axis; per-expert ffn replicated.
        ax = ("expert_sharded", "embed", "moe_ffn")
        ax_down = ("expert_sharded", "moe_ffn", "embed")
    else:  # TP-in-expert: experts replicated, per-expert ffn takes model axis
        ax = ("expert", "embed", "moe_ffn")
        ax_down = ("expert", "moe_ffn", "embed")
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ax),
        "w_up": ParamSpec((e, d, f), ax),
        "w_down": ParamSpec((e, f, d), ax_down),
    }


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(math.ceil(group * cfg.num_experts_per_tok / cfg.num_experts
                      * cfg.moe_capacity_factor))
    return max(4, min(group, c))


def moe_forward(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss). x: (B, S, D)."""
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    tokens = b * s
    g = min(cfg.moe_group_size, tokens)
    while tokens % g:
        g //= 2
    n_groups = tokens // g
    cap = _capacity(cfg, g)

    xt = x.reshape(n_groups, g, d)
    xt = shard(xt, "moe_groups", None, None)

    router_logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)            # (G, g, E)

    # --- aux loss (Switch-style load balancing) -----------------------------
    density = jnp.mean(probs, axis=1)                          # (G, E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=1)                              # (G, E)
    aux_loss = jnp.mean(jnp.sum(density * frac, axis=-1)) * e

    # --- top-k selection -----------------------------------------------------
    topw, topi = lax.top_k(probs, k)                           # (G, g, k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    # position of each (token, choice) inside its expert's capacity buffer
    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32)           # (G, g, k, E)
    # rank tokens per expert: flatten (g, k) in priority order (token-major)
    sel_flat = sel.reshape(n_groups, g * k, e)
    pos_in_expert = jnp.cumsum(sel_flat, axis=1) - sel_flat    # (G, g*k, E)
    pos_in_expert = pos_in_expert.reshape(n_groups, g, k, e)
    within_cap = pos_in_expert < cap
    cap_slot = jax.nn.one_hot(
        jnp.sum(pos_in_expert * sel, axis=-1).astype(jnp.int32),
        cap, dtype=jnp.float32)                                # (G, g, k, C)
    # One-hot routing tensors are piecewise constant: their cotangents are
    # zero a.e. but, if left differentiable, XLA materialises fp32
    # (G,g,E,C)-shaped gradient paths (44 GB/layer/device of all-reduce for
    # grok-1 — measured). Router gradient flows through `topw` only.
    sel_live = lax.stop_gradient(sel * within_cap)             # (G, g, k, E)
    cap_slot = lax.stop_gradient(cap_slot)                     # (G, g, k, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", sel_live, cap_slot)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", sel_live, cap_slot, topw)

    dispatch = dispatch.astype(dt)
    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xt)     # (E, G, C, D)
    expert_in = shard(expert_in, "expert_sharded", "moe_groups", None, None)

    act = act_fn(cfg.mlp_act)
    hg = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(dt))
    hu = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"].astype(dt))
    h = act(hg) * hu
    h = shard(h, "expert_sharded", "moe_groups", None, "moe_ffn_act")
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(dt))
    # NO sharding constraint on expert_out: under TP-in-expert its f-
    # contraction leaves per-shard partial sums, and constraining it here
    # forces an all-reduce of the fat (E,G,C,D) capacity tensor (measured:
    # 44 GB/layer/device fp32 on grok-1). Leaving it unconstrained lets
    # GSPMD carry the partial sums through the combine einsum and reduce
    # the (G,g,D) token tensor instead — ~5x fewer wire bytes.

    out = jnp.einsum("gtec,egcd->gtd", combine.astype(dt), expert_out)
    out = shard(out, "moe_groups", None, None)
    return out.reshape(b, s, d), aux_loss.astype(jnp.float32)
