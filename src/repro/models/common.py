"""Common building blocks shared by every architecture family.

Everything here is pure JAX (no flax): parameters are plain pytrees of
``jnp.ndarray`` leaves, and each parameter tree has a parallel *logical-axis*
tree (tuples of axis names) consumed by :mod:`repro.distributed.sharding` to
derive ``PartitionSpec`` trees for any mesh.

Design notes
------------
* Parameters are stored in ``param_dtype`` (fp32 master copies) and cast to
  ``dtype`` (bf16) at use — the standard mixed-precision recipe.
* Homogeneous layer stacks carry a leading ``layers`` dimension and are
  executed with ``jax.lax.scan`` so the HLO contains one layer body
  regardless of depth (essential for compile time at 512-way GSPMD).
* ``shard(x, *axes)`` inserts ``with_sharding_constraint`` with *logical*
  axes; it is a no-op outside a mesh context, so CPU unit tests run the
  exact same code path.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

VOCAB_PAD_MULTIPLE = 256


def pad_vocab(vocab_size: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    """Pad the embedding table so it divides any reasonable model axis."""
    return int(math.ceil(vocab_size / multiple) * multiple)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single config type covering all assigned architecture families."""

    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q/k
    qkv_bias: bool = False           # qwen2-style bias on qkv projections
    rope_theta: float = 10_000.0
    use_rope: bool = True
    attn_impl: str = "direct"        # direct | chunked | pallas
    attn_q_block: int = 512          # chunked/pallas q tile
    attn_kv_block: int = 512         # chunked/pallas kv tile
    attn_softcap: float = 0.0        # grok-style logit soft-capping

    # --- mlp ---------------------------------------------------------------
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp

    # --- scalar multipliers (granite) ---------------------------------------
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    attention_multiplier: float = 0.0   # 0 -> default 1/sqrt(head_dim)
    logits_scaling: float = 1.0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_group_size: int = 1024       # GShard-style dispatch group size
    moe_capacity_factor: float = 1.25

    # --- hybrid (recurrentgemma / griffin) ----------------------------------
    block_pattern: tuple[str, ...] = ()   # e.g. ('rglru', 'rglru', 'attn')
    local_window: int = 0
    d_rnn: int = 0
    conv_width: int = 4
    rnn_blocks: int = 16            # block-diagonal RG-LRU gate blocks

    # --- xlstm ---------------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_conv_width: int = 4
    mlstm_chunk: int = 128

    # --- enc-dec (whisper backbone) ------------------------------------------
    encoder_layers: int = 0
    num_frames: int = 0              # stub conv-frontend output length

    # --- vlm (llava backbone) -------------------------------------------------
    num_patches: int = 0             # stub anyres patch-embedding count

    # --- numerics / infra -----------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat_policy: str = "nothing_saveable"
    # Unroll layer stacks into straight-line HLO instead of lax.scan.
    # Used by the roofline measurement: XLA's cost analysis counts a scan
    # body ONCE (not x trip count), so collective/flop extraction lowers
    # small unrolled depths and extrapolates linearly in L.
    unroll_layers: bool = False
    # Chunked cross-entropy: compute logits+CE in sequence chunks of this
    # size under remat, so the (B, S, vocab) fp32 logits tensor is never
    # materialized. 0 = off.
    ce_chunk: int = 0
    use_pallas: bool = False
    # decode-attention inner product: 'direct' (einsum over the full cache)
    # or 'pallas' (the flash-decode kernel, ragged per-row kv lengths).
    decode_impl: str = "direct"
    kv_cache_dtype: str = "bfloat16"   # 'int8' enables quantised KV cache
    # Number of physical replications of KV heads so the KV-head dim divides
    # the model axis. 1 means no repetition. Set by the sharding resolver.
    kv_repeat: int = 1
    # attention sharding strategy: 'heads' (TP) or 'sequence' (context-parallel)
    attn_sharding: str = "heads"
    # MoE sharding strategy: 'expert' (EP) or 'ffn' (TP-in-expert)
    moe_sharding: str = "expert"

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def kv_heads_eff(self) -> int:
        """KV heads after physical repetition for shardability."""
        return self.num_kv_heads * self.kv_repeat

    @property
    def activation_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Logical-axis annotated parameter trees
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + logical axes + init for one parameter leaf."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | rglru_lambda
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Any      # pytree of jnp.ndarray
SpecTree = Any       # pytree of ParamSpec


def spec_shapes(spec_tree: SpecTree, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStruct tree for a spec tree (used by the dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def spec_axes(spec_tree: SpecTree) -> Any:
    return jax.tree.map(
        lambda s: s.axes, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def init_params(rng: jax.Array, spec_tree: SpecTree, dtype: jnp.dtype) -> ParamTree:
    """Materialise a parameter tree (only used for real, small runs)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        elif s.init == "rglru_lambda":
            # Initialise so that a = sigmoid(lambda)^(8*r) lands in (0.9, 0.999)
            u = jax.random.uniform(key, s.shape, dtype, 0.9, 0.999)
            a2 = u ** (1.0 / 8.0)
            out.append(jnp.log(a2 / (1.0 - a2)))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(1, fan_in))
            out.append(std * jax.random.normal(key, s.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def stacked(spec: ParamSpec, layers: int) -> ParamSpec:
    """Add a leading scanned-layer dimension to a spec."""
    return ParamSpec(
        shape=(layers, *spec.shape),
        axes=("layers", *spec.axes),
        init=spec.init,
        scale=spec.scale,
    )


def stack_specs(specs: Mapping[str, Any], layers: int) -> Any:
    return jax.tree.map(
        lambda s: stacked(s, layers), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Logical sharding constraints
# ---------------------------------------------------------------------------

class _AxisRulesState:
    """Thread-global logical→mesh axis rules; no-op when not installed."""

    def __init__(self) -> None:
        self.rules: dict[str, tuple[str, ...] | str | None] | None = None
        self.mesh = None

    def install(self, mesh, rules) -> None:
        self.mesh = mesh
        self.rules = dict(rules)

    def clear(self) -> None:
        self.mesh = None
        self.rules = None


_AXIS_RULES = _AxisRulesState()


def install_axis_rules(mesh, rules) -> None:
    _AXIS_RULES.install(mesh, rules)


def clear_axis_rules() -> None:
    _AXIS_RULES.clear()


class axis_rules:
    """Context manager installing logical axis rules for `shard()`."""

    def __init__(self, mesh, rules):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        install_axis_rules(self.mesh, self.rules)
        return self

    def __exit__(self, *exc):
        clear_axis_rules()
        return False


def logical_to_spec(axes: Sequence[str | None]):
    """Translate logical axis names into a PartitionSpec via active rules."""
    from jax.sharding import PartitionSpec as P

    rules = _AXIS_RULES.rules or {}
    parts = []
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        parts.append(r)
    return P(*parts)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Apply a logical sharding constraint; identity when no rules active."""
    if _AXIS_RULES.rules is None or _AXIS_RULES.mesh is None:
        return x
    spec = logical_to_spec(axes)
    from jax.sharding import NamedSharding

    return lax.with_sharding_constraint(x, NamedSharding(_AXIS_RULES.mesh, spec))


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(q: jax.Array, k: jax.Array, positions: jax.Array, theta: float):
    """Rotary embeddings. q: (..., S, H, hd), positions: (..., S)."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        xr1 = x1 * cos - x2 * sin
        xr2 = x2 * cos + x1 * sin
        return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)

    return rot(q), rot(k)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embeddings."""
    log_timescale = math.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


_ACTS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": partial(jax.nn.gelu, approximate=True),
    "gelu_exact": partial(jax.nn.gelu, approximate=False),
}


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return _ACTS[name]


# ---------------------------------------------------------------------------
# Remat policy resolution
# ---------------------------------------------------------------------------

def remat_policy(name: str):
    """Map a policy name onto a jax.checkpoint policy (None = save nothing)."""
    cp = jax.checkpoint_policies
    table = {
        "none": None,                         # plain jax.checkpoint default
        "nothing_saveable": cp.nothing_saveable,
        "dots_saveable": cp.dots_saveable,
        "dots_with_no_batch_dims_saveable": cp.dots_with_no_batch_dims_saveable,
        "everything_saveable": cp.everything_saveable,
    }
    if name not in table:
        raise ValueError(f"unknown remat policy {name!r}; options {sorted(table)}")
    return table[name]


def maybe_remat(fn, policy_name: str):
    if policy_name == "off":
        return fn
    policy = remat_policy(policy_name)
    if policy is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=policy)


def scan_or_unroll(body, carry, xs, *, unroll: bool):
    """lax.scan, or an unrolled python loop with identical semantics.

    Unrolling exists for roofline measurement (scan bodies are counted once
    by XLA cost analysis) — see ModelConfig.unroll_layers.
    """
    if not unroll:
        return lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, stacked


# ---------------------------------------------------------------------------
# Cross-entropy loss with padded-vocab masking
# ---------------------------------------------------------------------------

def softmax_cross_entropy(
    logits: jax.Array,       # (B, S, Vp) any float dtype
    labels: jax.Array,       # (B, S) int32
    mask: jax.Array | None,  # (B, S) float/bool, 1 = contributes
    vocab_size: int,
) -> tuple[jax.Array, jax.Array]:
    """Mean CE over masked tokens; padded vocab entries are neutralised."""
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp != vocab_size:
        pad_bias = jnp.where(
            jnp.arange(vp) < vocab_size, 0.0, -1e30
        ).astype(jnp.float32)
        logits = logits + pad_bias
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.sum(nll * mask)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return total / denom, denom
