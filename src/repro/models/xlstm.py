"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory).

Layout follows the paper's xLSTM[7:1] recipe: every 8th block is an sLSTM,
the rest are mLSTM. ``d_ff = 0`` per the assignment — blocks carry their own
internal up/down projections and there is no separate transformer FFN.

* mLSTM training path uses the **chunkwise-parallel** formulation (intra-chunk
  MXU matmuls + inter-chunk recurrence), which is what the Pallas kernel
  (kernels/mlstm_chunk) implements; the exact sequential recurrence lives in
  the kernel's ref.py and in :func:`mlstm_recurrent_ref` below for tests.
* sLSTM has a recurrent dependency on h_{t-1} and is inherently sequential —
  a ``lax.scan`` over time (the paper's CUDA kernel has the same structure).

Linear recurrences make this arch sub-quadratic, so it runs ``long_500k``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (
    ModelConfig,
    ParamSpec,
    layer_norm,
    maybe_remat,
    shard,
    softmax_cross_entropy,
)


def d_inner(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.mlstm_proj_factor)


def head_dim(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.num_heads


def slstm_positions(cfg: ModelConfig) -> set[int]:
    return {i for i in range(cfg.num_layers) if i % 8 == 7}


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def make_mlstm_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, di, h = cfg.d_model, d_inner(cfg), cfg.num_heads
    hd = di // h
    w = cfg.slstm_conv_width
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "ln_b": ParamSpec((d,), ("embed",), init="zeros"),
        "w_up": ParamSpec((d, 2 * di), ("embed", "xlstm_inner")),
        "conv_w": ParamSpec((w, di), (None, "xlstm_inner")),
        "conv_b": ParamSpec((di,), ("xlstm_inner",), init="zeros"),
        "w_q": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_k": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_v": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_i": ParamSpec((di, h), ("xlstm_inner", None)),
        "b_i": ParamSpec((h,), (None,), init="zeros"),
        "w_f": ParamSpec((di, h), ("xlstm_inner", None)),
        "b_f": ParamSpec((h,), (None,), init="ones"),
        "gn_scale": ParamSpec((di,), ("xlstm_inner",), init="ones"),
        "w_down": ParamSpec((di, d), ("xlstm_inner", "embed")),
    }


def make_slstm_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    w = cfg.slstm_conv_width
    dff = int(d * 4 / 3)
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "ln_b": ParamSpec((d,), ("embed",), init="zeros"),
        "conv_w": ParamSpec((w, d), (None, "embed")),
        "conv_b": ParamSpec((d,), ("embed",), init="zeros"),
        # gate input weights (block-diagonal per head) + recurrent weights
        "w_i": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_f": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_z": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "w_o": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "r_i": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "r_f": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "r_z": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "r_o": ParamSpec((h, hd, hd), (None, "xlstm_hd", "xlstm_hd_out")),
        "b_i": ParamSpec((d,), ("embed",), init="zeros"),
        "b_f": ParamSpec((d,), ("embed",), init="ones"),
        "b_z": ParamSpec((d,), ("embed",), init="zeros"),
        "b_o": ParamSpec((d,), ("embed",), init="zeros"),
        "gn_scale": ParamSpec((d,), ("embed",), init="ones"),
        "w_up1": ParamSpec((d, dff), ("embed", "ffn")),
        "w_up2": ParamSpec((d, dff), ("embed", "ffn")),
        "w_down": ParamSpec((dff, d), ("ffn", "embed")),
    }


def make_xlstm_specs(cfg: ModelConfig) -> dict[str, Any]:
    slstm = slstm_positions(cfg)
    layers = []
    for i in range(cfg.num_layers):
        if i in slstm:
            layers.append({"slstm": make_slstm_block_specs(cfg)})
        else:
            layers.append({"mlstm": make_mlstm_block_specs(cfg)})
    return {
        "embedding": ParamSpec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "layers": layers,
        "ln_final": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln_final_b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "lm_head": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _causal_conv(w: jax.Array, b: jax.Array, x: jax.Array,
                 state: jax.Array | None):
    width = w.shape[0]
    dt = x.dtype
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), dt)
           if state is None else state.astype(dt))
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros(x.shape, jnp.float32)
    for j in range(width):
        out = out + xp[:, j:j + x.shape[1], :].astype(jnp.float32) * \
            w[j].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return out.astype(dt), xp[:, xp.shape[1] - (width - 1):, :]


def _group_norm(x: jax.Array, scale: jax.Array, heads: int, eps: float = 1e-6):
    """Per-head group norm over the head dim. x: (..., heads*hd)."""
    dt = x.dtype
    shp = x.shape
    xh = x.reshape(*shp[:-1], heads, shp[-1] // heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(dt)


def _blockdiag(x: jax.Array, w: jax.Array) -> jax.Array:
    """Per-head linear. x: (..., H, hd); w: (H, hd, hd_out)."""
    return jnp.einsum("...hk,hko->...ho", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel (training) and sequential (reference)
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, li, lf, C0, n0, m0, chunk: int):
    """Stabilised chunkwise mLSTM.

    q,k,v: (B, H, S, hd); li, lf: (B, H, S) log input / log forget gates.
    C0: (B, H, hd, hd); n0: (B, H, hd); m0: (B, H).
    Returns h: (B, H, S, hd) and final (C, n, m).
    """
    bsz, h, s, hd = q.shape
    L = min(chunk, s)
    while s % L:
        L //= 2
    n_chunks = s // L
    f32 = jnp.float32

    qc = q.reshape(bsz, h, n_chunks, L, hd).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(bsz, h, n_chunks, L, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(bsz, h, n_chunks, L, hd).transpose(2, 0, 1, 3, 4)
    lic = li.reshape(bsz, h, n_chunks, L).transpose(2, 0, 1, 3).astype(f32)
    lfc = lf.reshape(bsz, h, n_chunks, L).transpose(2, 0, 1, 3).astype(f32)

    tri = jnp.tril(jnp.ones((L, L), bool))          # s <= tau
    tri_strict = jnp.tril(jnp.ones((L, L), bool), -1)

    def body(carry, xs):
        C, n, m = carry
        qb, kb, vb, lib, lfb = xs
        b_cum = jnp.cumsum(lfb, axis=-1)                       # (B,H,L) inclusive
        total = b_cum[..., -1:]                                 # (B,H,1)
        # decay from s+1..tau = b_tau - b_s ; gate at s = li_s
        # intra-chunk scores D[tau, s] = b_tau - b_s + li_s  (s <= tau)
        D = (b_cum[..., :, None] - b_cum[..., None, :] + lib[..., None, :])
        D = jnp.where(tri[None, None], D, -jnp.inf)
        # but diagonal: decay from s+1..tau with tau==s is 0 => b_tau-b_s=0 ok
        m_intra = jnp.max(D, axis=-1)                           # (B,H,L)
        m_inter = b_cum + m[..., None]                          # (B,H,L)
        m_out = jnp.maximum(m_intra, m_inter)
        m_out = jnp.maximum(m_out, -1e30)

        qf = qb.astype(f32) * (1.0 / float(hd) ** 0.5)
        # inter-chunk contribution
        inter_scale = jnp.exp(m_inter - m_out)                  # (B,H,L)
        h_inter = jnp.einsum("bhld,bhdv->bhlv", qf, C.astype(f32))
        den_inter = jnp.einsum("bhld,bhd->bhl", qf, n.astype(f32))
        # intra-chunk contribution
        P = jnp.exp(D - m_out[..., None])                       # (B,H,L,L)
        att = jnp.einsum("bhld,bhsd->bhls", qf, kb.astype(f32)) * P
        h_intra = jnp.einsum("bhls,bhsv->bhlv", att, vb.astype(f32))
        den_intra = jnp.sum(att, axis=-1)
        num = h_inter * inter_scale[..., None] + h_intra
        den = den_inter * inter_scale + den_intra
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
        h_out = num / denom[..., None]

        # state update (per-chunk stabiliser)
        m_state_cand = jnp.max(lib + total - b_cum, axis=-1)    # (B,H)
        m_new = jnp.maximum(m + total[..., 0], m_state_cand)
        c_scale = jnp.exp(m + total[..., 0] - m_new)            # (B,H)
        k_scale = jnp.exp(lib + total - b_cum - m_new[..., None])  # (B,H,L)
        kv = jnp.einsum("bhsd,bhsv,bhs->bhdv", kb.astype(f32), vb.astype(f32),
                        k_scale)
        C_new = C.astype(f32) * c_scale[..., None, None] + kv
        n_new = n.astype(f32) * c_scale[..., None] + \
            jnp.einsum("bhsd,bhs->bhd", kb.astype(f32), k_scale)
        return (C_new, n_new, m_new), h_out

    init = (C0.astype(f32), n0.astype(f32), m0.astype(f32))
    (C, n, m), hs = lax.scan(body, init, (qc, kc, vc, lic, lfc))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(bsz, h, s, hd)
    return hs.astype(q.dtype), (C, n, m)


def mlstm_recurrent_ref(q, k, v, li, lf, C0, n0, m0):
    """Exact sequential recurrence (oracle for the chunkwise forms)."""
    f32 = jnp.float32
    bsz, h, s, hd = q.shape
    scale = 1.0 / float(hd) ** 0.5

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)
        ip = jnp.exp(lit - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * \
            jnp.einsum("bhd,bhv->bhdv", kt.astype(f32), vt.astype(f32))
        n = fp[..., None] * n + ip[..., None] * kt.astype(f32)
        qf = qt.astype(f32) * scale
        num = jnp.einsum("bhd,bhdv->bhv", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                          jnp.exp(-m_new))
        return (C, n, m_new), (num / den[..., None])

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), li.transpose(2, 0, 1).astype(f32),
          lf.transpose(2, 0, 1).astype(f32))
    (C, n, m), hs = lax.scan(step, (C0.astype(f32), n0.astype(f32),
                                    m0.astype(f32)), xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype), (C, n, m)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _mlstm_qkv_gates(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                     conv_state=None):
    """x: (B, S, D) -> q,k,v (B,H,S,hd), gates (B,H,S), z, new conv state."""
    dt = x.dtype
    h = layer_norm(x, p["ln"], p["ln_b"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["w_up"].astype(dt))
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    # inner activations stay replicated on the model axis: the (B,S,di) ->
    # (B,S,H,hd) head reshape does not commute with a di-sharding, and this
    # is the smallest assigned model (DP carries it; see DESIGN.md).
    xm = shard(xm, "batch", "act_seq_rnn", None)
    xc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], xm, conv_state)
    xc = jax.nn.silu(xc)
    nh = cfg.num_heads
    hd = di // nh
    xch = xc.reshape(*xc.shape[:-1], nh, hd)
    xmh = xm.reshape(*xm.shape[:-1], nh, hd)
    q = _blockdiag(xch, p["w_q"]).transpose(0, 2, 1, 3)       # (B,H,S,hd)
    k = _blockdiag(xch, p["w_k"]).transpose(0, 2, 1, 3)
    v = _blockdiag(xmh, p["w_v"]).transpose(0, 2, 1, 3)
    f32 = jnp.float32
    ig = (xc.astype(f32) @ p["w_i"].astype(f32) + p["b_i"].astype(f32))
    fg = (xc.astype(f32) @ p["w_f"].astype(f32) + p["b_f"].astype(f32))
    li = ig.transpose(0, 2, 1)                                 # (B,H,S)
    lf = -jax.nn.softplus(-fg).transpose(0, 2, 1)              # log sigmoid
    return q, k, v, li, lf, z, new_conv


def mlstm_block_forward(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                        state: dict | None = None):
    dt = x.dtype
    bsz, s, _ = x.shape
    di = d_inner(cfg)
    nh = cfg.num_heads
    hd = di // nh
    conv_state = state["conv"] if state is not None else None
    q, k, v, li, lf, z, new_conv = _mlstm_qkv_gates(cfg, p, x, conv_state)
    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    else:
        C0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((bsz, nh, hd), jnp.float32)
        m0 = jnp.full((bsz, nh), -1e30, jnp.float32)
    if cfg.use_pallas and s > 1:
        from repro.kernels.mlstm_chunk import ops as ml_ops
        hs, (C, n, m) = ml_ops.mlstm_chunk(q, k, v, li, lf, C0, n0, m0,
                                           chunk=cfg.mlstm_chunk)
    elif s == 1:
        hs, (C, n, m) = mlstm_recurrent_ref(q, k, v, li, lf, C0, n0, m0)
    else:
        hs, (C, n, m) = mlstm_chunkwise(q, k, v, li, lf, C0, n0, m0,
                                        chunk=cfg.mlstm_chunk)
    hflat = hs.transpose(0, 2, 1, 3).reshape(bsz, s, di)
    hflat = _group_norm(hflat, p["gn_scale"], nh, cfg.norm_eps)
    out = hflat * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(dt))
    return out, {"C": C, "n": n, "m": m, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_cell_scan(p, xi, xf, xz, xo, state, nh: int):
    """Sequential sLSTM. x*: (B, S, D) fp32 gate pre-activations (input part).

    state: dict c,n,m,h of (B, D) fp32. Returns hs (B,S,D) and new state.
    """
    f32 = jnp.float32
    bsz, s, d = xi.shape
    hd = d // nh

    def to_heads(t):
        return t.reshape(bsz, nh, hd)

    def step(carry, xs):
        c, n, m, h = carry
        xit, xft, xzt, xot = xs
        hh = h.reshape(bsz, nh, hd)
        ri = _blockdiag(hh, p["r_i"]).reshape(bsz, d)
        rf = _blockdiag(hh, p["r_f"]).reshape(bsz, d)
        rz = _blockdiag(hh, p["r_z"]).reshape(bsz, d)
        ro = _blockdiag(hh, p["r_o"]).reshape(bsz, d)
        li = xit + ri
        lf_ = -jax.nn.softplus(-(xft + rf))       # log sigmoid forget
        z = jnp.tanh(xzt + rz)
        o = jax.nn.sigmoid(xot + ro)
        m_new = jnp.maximum(lf_ + m, li)
        fp = jnp.exp(lf_ + m - m_new)
        ip = jnp.exp(li - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    xs = (xi.transpose(1, 0, 2), xf.transpose(1, 0, 2),
          xz.transpose(1, 0, 2), xo.transpose(1, 0, 2))
    (c, n, m, h), hs = lax.scan(
        step, (state["c"], state["n"], state["m"], state["h"]), xs)
    return hs.transpose(1, 0, 2), {"c": c, "n": n, "m": m, "h": h}


def slstm_block_forward(cfg: ModelConfig, p: dict[str, Any], x: jax.Array,
                        state: dict | None = None):
    dt = x.dtype
    bsz, s, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    f32 = jnp.float32
    h = layer_norm(x, p["ln"], p["ln_b"], cfg.norm_eps)
    conv_state = state["conv"] if state is not None else None
    hc, new_conv = _causal_conv(p["conv_w"], p["conv_b"], h, conv_state)
    hc = jax.nn.silu(hc)
    hh = h.reshape(bsz, s, nh, hd)
    hch = hc.reshape(bsz, s, nh, hd)
    xi = _blockdiag(hch, p["w_i"]).reshape(bsz, s, d).astype(f32) + \
        p["b_i"].astype(f32)
    xf = _blockdiag(hch, p["w_f"]).reshape(bsz, s, d).astype(f32) + \
        p["b_f"].astype(f32)
    xz = _blockdiag(hh, p["w_z"]).reshape(bsz, s, d).astype(f32) + \
        p["b_z"].astype(f32)
    xo = _blockdiag(hh, p["w_o"]).reshape(bsz, s, d).astype(f32) + \
        p["b_o"].astype(f32)
    if state is None:
        zero = jnp.zeros((bsz, d), f32)
        cell = {"c": zero, "n": zero, "m": jnp.full((bsz, d), -1e30, f32),
                "h": zero}
    else:
        cell = {k2: state[k2] for k2 in ("c", "n", "m", "h")}
    hs, new_cell = slstm_cell_scan(p, xi, xf, xz, xo, cell, nh)
    hs = _group_norm(hs.astype(dt), p["gn_scale"], nh, cfg.norm_eps)
    # post up-projection (PF = 4/3), gated GeLU
    u1 = jnp.einsum("bsd,df->bsf", hs, p["w_up1"].astype(dt))
    u2 = jnp.einsum("bsd,df->bsf", hs, p["w_up2"].astype(dt))
    out = jax.nn.gelu(u1) * u2
    out = jnp.einsum("bsf,fd->bsd", out, p["w_down"].astype(dt))
    new_state = dict(new_cell)
    new_state["conv"] = new_conv
    return out, new_state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _forward_stack(cfg: ModelConfig, params, x, states=None):
    slstm = slstm_positions(cfg)
    new_states = []
    for i, p in enumerate(params["layers"]):
        st = states[i] if states is not None else None
        if i in slstm:
            fn = maybe_remat(
                lambda x, p, st: slstm_block_forward(cfg, p["slstm"], x, st),
                cfg.remat_policy)
            out, ns = fn(x, p, st)
        else:
            fn = maybe_remat(
                lambda x, p, st: mlstm_block_forward(cfg, p["mlstm"], x, st),
                cfg.remat_policy)
            out, ns = fn(x, p, st)
        x = x + out
        x = shard(x, "batch", "act_seq", None)
        new_states.append(ns)
    return x, new_states


def xlstm_forward(cfg: ModelConfig, params, batch):
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype),
                 batch["tokens"], axis=0)
    x = shard(x, "batch", "act_seq", None)
    x, _ = _forward_stack(cfg, params, x)
    x = layer_norm(x, params["ln_final"], params["ln_final_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return shard(logits, "batch", "act_seq", "vocab_sharded")


def xlstm_loss(cfg: ModelConfig, params, batch):
    logits = xlstm_forward(cfg, params, batch)
    loss, denom = softmax_cross_entropy(
        logits, batch["labels"], batch.get("mask"), cfg.vocab_size)
    return loss, {"ce_loss": loss, "tokens": denom,
                  "aux_loss": jnp.zeros((), jnp.float32)}


def init_xlstm_state(cfg: ModelConfig, batch: int, max_len: int):
    di = d_inner(cfg)
    nh = cfg.num_heads
    hd = di // nh
    d = cfg.d_model
    w = cfg.slstm_conv_width - 1
    f32 = jnp.float32
    states = []
    for i in range(cfg.num_layers):
        if i in slstm_positions(cfg):
            states.append({
                "c": jnp.zeros((batch, d), f32),
                "n": jnp.zeros((batch, d), f32),
                "m": jnp.full((batch, d), -1e30, f32),
                "h": jnp.zeros((batch, d), f32),
                "conv": jnp.zeros((batch, w, d), cfg.activation_dtype),
            })
        else:
            states.append({
                "C": jnp.zeros((batch, nh, hd, hd), f32),
                "n": jnp.zeros((batch, nh, hd), f32),
                "m": jnp.full((batch, nh), -1e30, f32),
                "conv": jnp.zeros((batch, w, di), cfg.activation_dtype),
            })
    return states


def xlstm_state_axes(cfg: ModelConfig):
    axes = []
    for i in range(cfg.num_layers):
        if i in slstm_positions(cfg):
            axes.append({"c": ("batch", None), "n": ("batch", None),
                         "m": ("batch", None), "h": ("batch", None),
                         "conv": ("batch", None, None)})
        else:
            axes.append({"C": ("batch", None, "xlstm_hd_sharded", None),
                         "n": ("batch", None, "xlstm_hd_sharded"),
                         "m": ("batch", None),
                         "conv": ("batch", None, "xlstm_inner_sharded")})
    return axes


def xlstm_prefill(cfg: ModelConfig, params, batch, states):
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype),
                 batch["tokens"], axis=0)
    x, new_states = _forward_stack(cfg, params, x, states)
    x = layer_norm(x[:, -1:], params["ln_final"], params["ln_final_b"],
                   cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, new_states


def xlstm_decode_step(cfg: ModelConfig, params, states, tokens, pos):
    del pos  # recurrent state carries position implicitly
    x = jnp.take(params["embedding"].astype(cfg.activation_dtype),
                 tokens, axis=0)
    x, new_states = _forward_stack(cfg, params, x, states)
    x = layer_norm(x, params["ln_final"], params["ln_final_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, new_states
