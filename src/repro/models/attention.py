"""Multi-head / grouped-query attention for all architecture families.

Three interchangeable implementations (``cfg.attn_impl``):

* ``direct``  — one einsum; right choice for short sequences / smoke tests.
* ``chunked`` — memory-efficient online-softmax scan over KV blocks
                (flash-attention recurrence in pure JAX). This keeps the
                lowered HLO's temporary footprint ``O(S · kv_block)`` instead
                of ``O(S²)`` so the 32k prefill cells are roofline-sane.
* ``pallas``  — the fused Pallas TPU kernel (kernels/flash_attention).

GQA KV-head *physical repetition*: when the KV-head count does not divide
the model axis, k/v activations (and the KV cache) are tiled ``kv_repeat``
times so they shard. Weights keep the architecture's true KV-head count, so
the math is unchanged — the repeat is purely a layout transformation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (
    ModelConfig,
    ParamSpec,
    rms_norm,
    rope,
    shard,
)

NEG_INF = -2.0e30


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------

def make_attn_specs(cfg: ModelConfig, *, cross: bool = False) -> dict[str, ParamSpec]:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    specs: dict[str, ParamSpec] = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads_w", "head_dim")),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads_w", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((hkv, hd), ("kv_heads_w", "head_dim"), init="zeros")
        specs["bv"] = ParamSpec((hkv, hd), ("kv_heads_w", "head_dim"), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return specs


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
                 kv_x: jax.Array | None = None):
    """Project to q, k, v; apply qk-norm; tile kv heads to kv_heads_eff."""
    dt = x.dtype
    kv_in = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.kv_repeat > 1:
        # Physical tiling for shardability; consecutive-group semantics match
        # the (Hkv, G) query grouping below.
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    return q, k, v


def _shard_qkv(cfg: ModelConfig, q, k, v):
    if cfg.attn_sharding == "heads":
        q = shard(q, "batch", None, "heads_sharded", None)
        k = shard(k, "batch", None, "kv_heads_sharded", None)
        v = shard(v, "batch", None, "kv_heads_sharded", None)
    else:  # sequence/context parallel: shard q along seq, kv batch-only
        q = shard(q, "batch", "seq_sharded", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    return q, k, v


# ---------------------------------------------------------------------------
# Masking helpers
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
               window: int, kv_len: jax.Array | None) -> jax.Array:
    """(Sq, Skv) additive bias in fp32. kv_len masks out unwritten cache."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_len is not None:
        ok &= (k_pos < kv_len)[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Core attention math (grouped)
# ---------------------------------------------------------------------------

def _direct_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, causal,
                      window, kv_len=None) -> jax.Array:
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = cfg.attention_multiplier or (1.0 / float(hd) ** 0.5)
    qg = q.reshape(b, sq, hkv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    logits = logits + _mask_bias(q_pos, k_pos, causal=causal, window=window,
                                 kv_len=kv_len)[None, None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _chunked_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, causal,
                       window, kv_len=None) -> jax.Array:
    """Online-softmax scan over KV blocks; O(Sq·kv_block) temporaries."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    blk = min(cfg.attn_kv_block, skv)
    while skv % blk:
        blk //= 2
    nblk = skv // blk
    scale = cfg.attention_multiplier or (1.0 / float(hd) ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, hd)

    def body(carry, j):
        m, l, acc = carry
        kb = lax.dynamic_slice_in_dim(k, j * blk, blk, axis=1)
        vb = lax.dynamic_slice_in_dim(v, j * blk, blk, axis=1)
        kp = lax.dynamic_slice_in_dim(k_pos, j * blk, blk, axis=0)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(q.dtype), kb)
        logits = logits.astype(jnp.float32)
        logits = _softcap(logits, cfg.attn_softcap)
        logits = logits + _mask_bias(q_pos, kp, causal=causal, window=window,
                                     kv_len=kv_len)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(q.dtype), vb)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nblk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (b, hkv, g, sq, hd) -> (b, sq, h, hd)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)

    # NOTE: scale was already folded into qg before the scan.


def _pallas_attention(cfg: ModelConfig, q, k, v, q_pos, k_pos, *, causal,
                      window, kv_len=None) -> jax.Array:
    from repro.kernels.flash_attention import ops as fa_ops

    if kv_len is not None or not causal:
        # Cache-masked / non-causal paths stay on the chunked implementation.
        return _chunked_attention(cfg, q, k, v, q_pos, k_pos, causal=causal,
                                  window=window, kv_len=kv_len)
    scale = cfg.attention_multiplier or (1.0 / float(q.shape[-1]) ** 0.5)
    return fa_ops.flash_attention(
        q, k, v, causal=True, window=window, scale=scale,
        softcap=cfg.attn_softcap, q_offset=q_pos[0],
        block_q=cfg.attn_q_block, block_kv=cfg.attn_kv_block,
    )


_IMPLS = {
    "direct": _direct_attention,
    "chunked": _chunked_attention,
    "pallas": _pallas_attention,
}


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attn_forward(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
                 positions: jax.Array, *, causal: bool = True,
                 window: int = 0, kv_x: jax.Array | None = None,
                 kv_positions: jax.Array | None = None) -> jax.Array:
    """Full (train/prefill) attention. x: (B, S, D)."""
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if cfg.use_rope and kv_x is None:
        q, k = rope(q, k, positions, cfg.rope_theta)
    q, k, v = _shard_qkv(cfg, q, k, v)
    k_pos = positions if kv_positions is None else kv_positions
    impl = _IMPLS[cfg.attn_impl]
    out = impl(cfg, q, k, v, positions, k_pos, causal=causal, window=window)
    if cfg.attn_sharding == "heads":
        out = shard(out, "batch", None, "heads_sharded", None)
    else:
        out = shard(out, "batch", "seq_sharded", None, None)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per (token, head) int8 symmetric quantisation along head_dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  *, layers: int | None = None) -> dict[str, Any]:
    """Cache pytree (ShapeDtypeStruct-compatible via jax.eval_shape)."""
    hkv, hd = cfg.kv_heads_eff, cfg.hd
    shape = (batch, max_len, hkv, hd)
    if layers is not None:
        shape = (layers, *shape)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, cfg.activation_dtype),
        "v": jnp.zeros(shape, cfg.activation_dtype),
    }


def kv_cache_axes(cfg: ModelConfig, *, layers: bool = True) -> dict[str, tuple]:
    """Logical axes of the cache (leading 'layers' when stacked)."""
    lead = ("layers",) if layers else ()
    if cfg.attn_sharding == "heads":
        ax = lead + ("kv_batch", None, "kv_heads_sharded", None)
    else:
        ax = lead + ("kv_batch", "kv_seq_sharded", None, None)
    out = {"k": ax, "v": ax}
    if cfg.kv_cache_dtype == "int8":
        out["k_scale"] = ax[:-1] + (None,)
        out["v_scale"] = ax[:-1] + (None,)
    return out


def _cache_write(cache: dict[str, jax.Array], k: jax.Array, v: jax.Array,
                 pos: jax.Array, quantized: bool) -> dict[str, jax.Array]:
    """Write one new (B, 1, Hkv, hd) k/v at index pos (ring handled upstream).

    ``pos`` may be a scalar (all rows at the same depth) or a (B,) vector —
    the continuous-batching case where every slot sits at its own position.
    """
    per_row = getattr(pos, "ndim", 0) == 1

    def put(buf: jax.Array, upd: jax.Array) -> jax.Array:
        if per_row:
            return jax.vmap(
                lambda c, u, p: lax.dynamic_update_slice_in_dim(c, u, p,
                                                                axis=0)
            )(buf, upd, pos)
        return lax.dynamic_update_slice_in_dim(buf, upd, pos, axis=1)

    if quantized:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {
            "k": put(cache["k"], kq),
            "v": put(cache["v"], vq),
            "k_scale": put(cache["k_scale"], ks),
            "v_scale": put(cache["v_scale"], vs),
        }
    return {
        "k": put(cache["k"], k),
        "v": put(cache["v"], v),
    }


def _cache_read(cfg: ModelConfig, cache: dict[str, jax.Array]):
    if cfg.kv_cache_dtype == "int8":
        k = dequantize_kv(cache["k"], cache["k_scale"], cfg.activation_dtype)
        v = dequantize_kv(cache["v"], cache["v_scale"], cfg.activation_dtype)
        return k, v
    return cache["k"], cache["v"]


def attn_decode(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
                cache: dict[str, jax.Array], pos: jax.Array, *,
                window: int = 0) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 current position,
    or a (B,) int32 vector of *per-row* positions (continuous batching —
    each slot writes its k/v at, and attends up to, its own depth).

    For ``window > 0`` the cache is a ring buffer of length ``window`` —
    entries are written at ``pos % window`` and masked by recency. Ring
    buffers require a scalar ``pos`` (all rows advance in lockstep).
    """
    b = x.shape[0]
    per_row = getattr(pos, "ndim", 0) == 1
    if per_row and window > 0:
        raise ValueError("per-row decode positions are incompatible with "
                         "ring-buffer (windowed) KV caches")
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.use_rope:
        if per_row:
            posv = pos.astype(jnp.int32)[:, None]          # (B, 1)
        else:
            posv = jnp.full((1,), pos, jnp.int32)[None, :]  # (1, 1)
        q, k = rope(q, k, posv, cfg.rope_theta)

    max_len = cache["k"].shape[1]
    write_pos = (pos % window) if window > 0 else pos
    cache = _cache_write(cache, k, v, write_pos, cfg.kv_cache_dtype == "int8")
    ck, cv = _cache_read(cfg, cache)

    # decode activations follow the CACHE's batch sharding (kv_batch): in
    # serve2d mode the residual stream is replicated but attention must run
    # batch-sharded against the sharded cache (GSPMD otherwise gathers it).
    if cfg.attn_sharding == "heads":
        ck = shard(ck, "kv_batch", None, "kv_heads_sharded", None)
        cv = shard(cv, "kv_batch", None, "kv_heads_sharded", None)
        q = shard(q, "kv_batch", None, "heads_sharded", None)
    else:
        ck = shard(ck, "kv_batch", "kv_seq_sharded", None, None)
        cv = shard(cv, "kv_batch", "kv_seq_sharded", None, None)
        q = shard(q, "kv_batch", None, None, None)

    hkv = ck.shape[2]
    h = q.shape[2]
    g = h // hkv
    hd = q.shape[-1]
    scale = cfg.attention_multiplier or (1.0 / float(hd) ** 0.5)

    # Flash-decode Pallas kernel path: ragged per-row lengths land directly
    # on the kernel's scalar-prefetch lens argument. Ring buffers and
    # soft-capping stay on the masked-einsum path below.
    if cfg.decode_impl == "pallas" and window == 0 and not cfg.attn_softcap:
        from repro.kernels.decode_attention import ops as da_ops

        kv_len = (pos if per_row else jnp.broadcast_to(pos, (b,))) + 1
        out = da_ops.decode_attention(q[:, 0], ck, cv,
                                      kv_len.astype(jnp.int32),
                                      scale=float(scale),
                                      block_kv=cfg.attn_kv_block)
        out = out[:, None]                                  # (B, 1, H, hd)
        out = shard(out, "kv_batch", None, "heads_sharded", None)
        dt = x.dtype
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), cache

    # slot -> absolute position (ring buffers wrap)
    slots = jnp.arange(max_len, dtype=jnp.int32)
    if window > 0:
        cycle = (pos // window) * window
        k_pos = jnp.where(slots <= (pos % window), cycle + slots,
                          cycle - window + slots)
        kv_len = None
        valid = (k_pos >= 0) & (k_pos > pos - window) & (k_pos <= pos)
    elif per_row:
        valid = slots[None, :] <= pos[:, None]              # (B, Smax)
    else:
        valid = slots <= pos

    qg = q.reshape(b, 1, hkv, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, ck).astype(jnp.float32) * scale
    logits = _softcap(logits, cfg.attn_softcap)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    if per_row:
        logits = logits + bias[:, None, None, None, :]
    else:
        logits = logits + bias[None, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, cv).reshape(b, 1, h, hd)
    out = shard(out, "kv_batch", None, "heads_sharded", None)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), cache


def prefill_into_cache(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
                       positions: jax.Array, cache: dict[str, jax.Array], *,
                       window: int = 0):
    """Prefill attention that also populates the cache for later decode."""
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.use_rope:
        q, k = rope(q, k, positions, cfg.rope_theta)
    q, k, v = _shard_qkv(cfg, q, k, v)
    s = x.shape[1]
    quantized = cfg.kv_cache_dtype == "int8"
    if window > 0:
        # keep the last `window` entries in ring order
        w = min(window, s)
        ks, vs = k[:, s - w:], v[:, s - w:]
        start = (s - w) % window if window else 0
        # ring layout: slot (pos % window); since we write a contiguous tail,
        # roll so that slot indices line up.
        idx = (jnp.arange(w) + (s - w)) % window
        order = jnp.argsort(idx)
        ks, vs = ks[:, order], vs[:, order]
        if quantized:
            kq, ksc = quantize_kv(ks)
            vq, vsc = quantize_kv(vs)
            cache = dict(cache)
            cache["k"] = lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, 1)
            cache["v"] = lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, 1)
            cache["k_scale"] = lax.dynamic_update_slice_in_dim(cache["k_scale"], ksc, 0, 1)
            cache["v_scale"] = lax.dynamic_update_slice_in_dim(cache["v_scale"], vsc, 0, 1)
        else:
            cache = dict(cache)
            cache["k"] = lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, 1)
            cache["v"] = lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, 1)
    else:
        if quantized:
            kq, ksc = quantize_kv(k)
            vq, vsc = quantize_kv(v)
            cache = {
                "k": lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, 1),
                "v": lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, 1),
                "k_scale": lax.dynamic_update_slice_in_dim(cache["k_scale"], ksc, 0, 1),
                "v_scale": lax.dynamic_update_slice_in_dim(cache["v_scale"], vsc, 0, 1),
            }
        else:
            cache = {
                "k": lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                "v": lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
            }
    impl = _IMPLS[cfg.attn_impl]
    out = impl(cfg, q, k, v, positions[0] if positions.ndim > 1 else positions,
               positions[0] if positions.ndim > 1 else positions,
               causal=True, window=window)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), cache
