"""Uniform model interface over all architecture families.

Every family exposes the same five entry points so the training loop,
serving loop, launcher and dry-run treat architectures opaquely (the same
way AiiDA's engine treats simulation codes opaquely — criterion (ii) of the
paper):

    loss_fn(params, batch)                  -> (loss, metrics)
    prefill_fn(params, batch, cache)        -> (logits, cache)
    decode_fn(params, cache, tokens, pos)   -> (logits, cache)
    init_cache(batch_size, max_len)         -> cache pytree
    cache_axes()                            -> logical-axis pytree for cache
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, rglru, transformer, xlstm
from repro.models.common import ModelConfig, spec_axes, spec_shapes

LM_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# Families whose attention cost is sub-quadratic (may run long_500k).
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    specs: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_cache: Callable
    cache_axes: Callable

    # -- parameter helpers ---------------------------------------------------
    def param_shapes(self):
        return spec_shapes(self.specs, self.cfg.weight_dtype)

    def param_axes(self):
        return spec_axes(self.specs)

    def init_params(self, rng: jax.Array):
        from repro.models.common import init_params
        return init_params(rng, self.specs, self.cfg.weight_dtype)

    # -- input specs (ShapeDtypeStruct stand-ins, no allocation) -------------
    def batch_struct(self, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        bf = cfg.activation_dtype
        if cell.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "vlm":
            s_text = max(s - cfg.num_patches, 16)
            return {
                "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
                "labels": jax.ShapeDtypeStruct((b, s_text), i32),
                "patches": jax.ShapeDtypeStruct((b, cfg.num_patches,
                                                 cfg.d_model), bf),
            }
        if cfg.family == "audio":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "frames": jax.ShapeDtypeStruct((b, cfg.num_frames,
                                                cfg.d_model), bf),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }

    def batch_axes(self, cell: ShapeCell) -> dict[str, tuple]:
        cfg = self.cfg
        if cell.kind == "decode":
            return {"tokens": ("batch", None)}
        out: dict[str, tuple] = {"tokens": ("batch", None),
                                 "labels": ("batch", None)}
        if cfg.family == "vlm":
            out["patches"] = ("batch", None, None)
        if cfg.family == "audio":
            out["frames"] = ("batch", None, None)
        return out

    def supports_cell(self, cell: ShapeCell) -> tuple[bool, str]:
        if cell.name == "long_500k" and \
                self.cfg.family not in SUBQUADRATIC_FAMILIES:
            return False, "full attention is O(S^2); long_500k assigned to " \
                          "sub-quadratic families only (see DESIGN.md)"
        return True, ""


# ---------------------------------------------------------------------------
# Family wiring
# ---------------------------------------------------------------------------

def build(cfg: ModelConfig) -> ModelBundle:
    if cfg.family in LM_FAMILIES:
        return ModelBundle(
            cfg=cfg,
            specs=transformer.make_lm_specs(cfg),
            loss_fn=lambda p, b: transformer.lm_loss(cfg, p, b),
            prefill_fn=lambda p, b, c: transformer.lm_prefill(cfg, p, b, c),
            decode_fn=lambda p, c, t, pos: transformer.lm_decode_step(
                cfg, p, c, t, pos),
            init_cache=lambda bsz, ml: transformer.init_lm_cache(cfg, bsz, ml),
            cache_axes=lambda: transformer.lm_cache_axes(cfg),
        )
    if cfg.family == "hybrid":
        return ModelBundle(
            cfg=cfg,
            specs=rglru.make_griffin_specs(cfg),
            loss_fn=lambda p, b: rglru.griffin_loss(cfg, p, b),
            prefill_fn=lambda p, b, c: rglru.griffin_prefill(cfg, p, b, c),
            decode_fn=lambda p, c, t, pos: rglru.griffin_decode_step(
                cfg, p, c, t, pos),
            init_cache=lambda bsz, ml: rglru.init_griffin_state(cfg, bsz, ml),
            cache_axes=lambda: rglru.griffin_state_axes(cfg),
        )
    if cfg.family == "ssm":
        return ModelBundle(
            cfg=cfg,
            specs=xlstm.make_xlstm_specs(cfg),
            loss_fn=lambda p, b: xlstm.xlstm_loss(cfg, p, b),
            prefill_fn=lambda p, b, c: xlstm.xlstm_prefill(cfg, p, b, c),
            decode_fn=lambda p, c, t, pos: xlstm.xlstm_decode_step(
                cfg, p, c, t, pos),
            init_cache=lambda bsz, ml: xlstm.init_xlstm_state(cfg, bsz, ml),
            cache_axes=lambda: xlstm.xlstm_state_axes(cfg),
        )
    if cfg.family == "audio":
        return ModelBundle(
            cfg=cfg,
            specs=encdec.make_whisper_specs(cfg),
            loss_fn=lambda p, b: encdec.whisper_loss(cfg, p, b),
            prefill_fn=lambda p, b, c: encdec.whisper_prefill(cfg, p, b, c),
            decode_fn=lambda p, c, t, pos: encdec.whisper_decode_step(
                cfg, p, c, t, pos),
            init_cache=lambda bsz, ml: encdec.init_whisper_cache(cfg, bsz, ml),
            cache_axes=lambda: encdec.whisper_cache_axes(cfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")
