from repro.provenance.store import (  # noqa: F401
    LinkType,
    NodeType,
    ProvenanceStore,
    QueryBuilder,
    configure_store,
    current_store,
)
