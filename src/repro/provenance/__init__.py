from repro.provenance.store import (  # noqa: F401
    LinkType,
    NodeType,
    ProvenanceStore,
    QueryBuilder,
    configure_store,
    current_store,
)
from repro.provenance.archive import (  # noqa: F401
    ARCHIVE_VERSION,
    ArchiveError,
    ImportResult,
    compute_closure,
    export_archive,
    import_archive,
    read_manifest,
)
