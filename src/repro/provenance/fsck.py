"""``repro store fsck [--repair]`` — offline self-healing for a profile.

The chaos invariant checker (:mod:`repro.chaos.invariants`) *judges* a
store; this module *fixes* one. It covers the corruption classes a
half-dead deployment can leave behind — a worker fleet wiped out past the
broker's requeue horizon, a broker database deleted, a kill -9 landing
between two stores' commits — and the housekeeping debt the engine never
pays on the hot path (unreferenced repository blobs).

Findings and repairs:

``orphan``
    A non-terminal process with no live lease and no pending task row in
    the broker database (or no broker database at all): nothing will ever
    run it again. Repair: if it still has a checkpoint AND a broker
    database was given, enqueue a fresh ``ready`` task row — the next
    daemon delivers it at a bumped epoch and the process resumes; without
    a checkpoint (or without a broker) it is marked ``excepted`` with
    exit status 999 and a terminal state-history entry, so waiters and
    queries see a truthful terminal record instead of a forever-pending
    ghost.

``stale-checkpoint``
    A terminal process still carrying a checkpoint (the terminal
    transaction tore before checkpoint removal landed, or a legacy bug).
    Repair: NULL the checkpoint — a terminal process must never be
    resumable.

``dangling-link``
    A link row whose endpoint node does not exist. Repair: delete the
    link row.

``unreferenced-blob``
    A repository blob no payload references (deleted nodes, crashed
    half-writes, superseded cache clones). Repair: delete the blob —
    closes the ROADMAP blob-GC follow-up. Reference scanning walks every
    payload's ``blob`` / ``blobs`` fields, so a blob is only collected
    when *no* row points at it.

Everything runs as raw SQL over the store (and optionally the broker
sqlite), independent of the engine code paths being repaired, and is
idempotent: a second ``fsck --repair`` over a repaired profile finds
nothing.
"""

from __future__ import annotations

import json
import os
import sqlite3
import time
from dataclasses import dataclass, field

from repro.core.statemachine import TERMINAL_STATES

#: mirror of repro.engine.daemon.PROCESS_QUEUE without importing the
#: engine (fsck must work on a profile with no engine running)
PROCESS_QUEUE = "process.queue"

STATE_HISTORY_ATTR = "state_history"

_TERMINAL = tuple(s.value for s in TERMINAL_STATES)


@dataclass
class FsckFinding:
    kind: str
    pk: int | None
    detail: str
    #: what --repair did ("" when running detect-only)
    action: str = ""

    def __str__(self) -> str:  # pragma: no cover - formatting
        where = f"pk={self.pk}: " if self.pk is not None else ""
        fixed = f" -> {self.action}" if self.action else ""
        return f"[{self.kind}] {where}{self.detail}{fixed}"


@dataclass
class FsckReport:
    findings: list[FsckFinding] = field(default_factory=list)
    repaired: bool = False
    checked_processes: int = 0
    checked_links: int = 0
    checked_blobs: int = 0
    notes: list[str] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.findings

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def add(self, kind: str, pk: int | None, detail: str,
            action: str = "") -> FsckFinding:
        finding = FsckFinding(kind, pk, detail, action)
        self.findings.append(finding)
        return finding

    def summary(self) -> str:
        verb = "repaired" if self.repaired else "found"
        lines = [
            f"processes checked : {self.checked_processes}",
            f"links checked     : {self.checked_links}",
            f"blobs checked     : {self.checked_blobs}",
            f"findings ({verb}) : {len(self.findings)}"
            + ("  " + ", ".join(f"{k}={v}"
                                for k, v in sorted(self.counts().items()))
               if self.findings else ""),
        ]
        for note in self.notes:
            lines.append(f"note: {note}")
        for f in self.findings[:100]:
            lines.append(f"  - {f}")
        if len(self.findings) > 100:
            lines.append(f"  ... and {len(self.findings) - 100} more")
        return "\n".join(lines)


def _live_pks_from_broker(broker_db: str) -> tuple[set[int], bool]:
    """pks the broker still intends to run: held leases + any pending
    (ready or inflight) task row in the process queue. Returns
    ``(pks, available)`` — ``available=False`` when the broker database
    could not be read (fsck then assumes nothing is live)."""
    if not broker_db or not os.path.exists(broker_db):
        return set(), False
    live: set[int] = set()
    try:
        conn = sqlite3.connect(broker_db, timeout=10.0)
        conn.row_factory = sqlite3.Row
        try:
            for row in conn.execute(
                    "SELECT pk FROM leases WHERE worker IS NOT NULL"):
                live.add(int(row["pk"]))
            for row in conn.execute(
                    "SELECT payload FROM tasks WHERE queue=?",
                    (PROCESS_QUEUE,)):
                try:
                    payload = json.loads(row["payload"])
                except ValueError:
                    continue
                if isinstance(payload, dict) and "pk" in payload:
                    live.add(int(payload["pk"]))
        finally:
            conn.close()
    except sqlite3.Error:
        return set(), False
    return live, True


def _requeue(broker_db: str, pk: int) -> None:
    """Insert one fresh ready task row — the standard delivery path then
    grants a (bumped) lease epoch when a worker picks it up."""
    conn = sqlite3.connect(broker_db, timeout=10.0)
    try:
        conn.execute(
            "INSERT INTO tasks (queue, payload, state, created_at)"
            " VALUES (?, ?, 'ready', ?)",
            (PROCESS_QUEUE, json.dumps({"pk": pk, "ts": time.time()}),
             time.time()))
        conn.commit()
    finally:
        conn.close()


def _mark_excepted(conn: sqlite3.Connection, pk: int, attrs: dict,
                   detail: str) -> None:
    """Terminal-ize an unrecoverable orphan: excepted, exit 999, history
    closed with a terminal entry, checkpoint removed — the same shape a
    live EXCEPTED transition writes, so every invariant holds after."""
    history = list(attrs.get(STATE_HISTORY_ATTR) or [])
    history.append(["excepted", time.time()])
    attrs = dict(attrs)
    attrs[STATE_HISTORY_ATTR] = history
    attrs.pop("paused", None)
    conn.execute(
        "UPDATE nodes SET process_state='excepted', exit_status=999,"
        " exit_message=?, checkpoint=NULL, attributes=? WHERE pk=?",
        (f"fsck: {detail}", json.dumps(attrs), pk))


def fsck(store, *, repair: bool = False,
         broker_db: str | None = None) -> FsckReport:
    """Scan ``store`` for the four corruption classes; with ``repair``,
    fix each finding in place. ``broker_db`` (the daemon's broker sqlite)
    enables live-lease detection and checkpoint requeue — without it
    every non-terminal process counts as orphaned and repair can only
    mark them excepted."""
    report = FsckReport(repaired=repair)
    live, broker_ok = _live_pks_from_broker(broker_db or "")
    if broker_db and not broker_ok:
        report.notes.append(
            f"broker db {broker_db!r} unreadable; assuming no live leases")
    if not broker_db:
        report.notes.append(
            "no broker db given: every non-terminal process counts as "
            "orphaned and repair marks them excepted (no requeue target)")

    with store._lock:
        conn = store._conn()

        # -- 1. orphaned non-terminal processes ----------------------------
        rows = conn.execute(
            "SELECT pk, process_state, checkpoint, attributes FROM nodes"
            " WHERE node_type LIKE 'process%'").fetchall()
        report.checked_processes = len(rows)
        marks = ",".join("?" * len(_TERMINAL))
        for row in rows:
            state = row["process_state"]
            if state in _TERMINAL:
                continue
            pk = row["pk"]
            if pk in live:
                continue
            has_ckpt = row["checkpoint"] is not None
            detail = (f"non-terminal (state={state!r}) with no live lease "
                      f"and no pending task")
            finding = report.add("orphan", pk, detail)
            if not repair:
                continue
            if has_ckpt and broker_ok:
                _requeue(broker_db, pk)
                finding.action = "requeued from checkpoint"
            else:
                try:
                    attrs = json.loads(row["attributes"] or "{}")
                except ValueError:
                    attrs = {}
                _mark_excepted(conn, pk, attrs,
                               "orphaned with no recoverable checkpoint"
                               if not has_ckpt else
                               "orphaned and no broker to requeue into")
                finding.action = "marked excepted (exit 999)"

        # -- 2. stale checkpoints of terminal processes --------------------
        for row in conn.execute(
                f"SELECT pk, process_state FROM nodes WHERE node_type LIKE"
                f" 'process%' AND process_state IN ({marks})"
                " AND checkpoint IS NOT NULL", list(_TERMINAL)).fetchall():
            finding = report.add(
                "stale-checkpoint", row["pk"],
                f"terminal (state={row['process_state']!r}) but still "
                "checkpointed")
            if repair:
                conn.execute("UPDATE nodes SET checkpoint=NULL WHERE pk=?",
                             (row["pk"],))
                finding.action = "checkpoint removed"

        # -- 3. dangling links ---------------------------------------------
        report.checked_links = conn.execute(
            "SELECT COUNT(*) AS n FROM links").fetchone()["n"]
        for col in ("in_id", "out_id"):
            for row in conn.execute(
                    f"SELECT l.rowid AS rid, l.{col} AS pk, l.link_type"
                    f" FROM links l LEFT JOIN nodes n ON n.pk = l.{col}"
                    " WHERE n.pk IS NULL").fetchall():
                finding = report.add(
                    "dangling-link", row["pk"],
                    f"{row['link_type']} link references missing node "
                    f"via {col}")
                if repair:
                    conn.execute("DELETE FROM links WHERE rowid=?",
                                 (row["rid"],))
                    finding.action = "link deleted"

        # -- 4. unreferenced repository blobs ------------------------------
        referenced: set[str] = set()
        for row in conn.execute(
                "SELECT payload FROM nodes WHERE payload IS NOT NULL"
                " AND payload LIKE '%blob%'"):
            try:
                doc = json.loads(row["payload"])
            except ValueError:
                continue
            if not isinstance(doc, dict):
                continue
            digest = doc.get("blob")
            if isinstance(digest, str):
                referenced.add(digest)
            blobs = doc.get("blobs")
            if isinstance(blobs, dict):
                referenced.update(d for d in blobs.values()
                                  if isinstance(d, str))
        for digest in list(store.repository.digests()):
            report.checked_blobs += 1
            if digest in referenced:
                continue
            finding = report.add(
                "unreferenced-blob", None,
                f"blob {digest[:12]}… referenced by no payload")
            if repair:
                store.repository.delete(digest)
                finding.action = "blob deleted"

        if repair:
            conn.commit()
    return report
