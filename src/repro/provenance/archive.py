"""Provenance archives: export / import between profiles (AiiDA 1.0 §export).

Provenance is only valuable if it travels: the engine records the full
directed graph of calculations and data precisely so results can be
shared, re-imported and *reused* elsewhere. An archive is a versioned zip
holding a closed subgraph — every exported process node carries its
complete input set — plus logs, array payloads and the ``node_hash`` /
``cached_from`` cache metadata. Importing an archive into another
profile's store merges the graph (nodes keep their uuid, pks are
remapped) and makes every imported finished-ok node an immediate cache
source: one user's computed results short-circuit another profile's
launches through the ordinary :class:`~repro.caching.registry.CacheRegistry`
lookup.

Archive layout (``ARCHIVE_VERSION`` 1)::

    manifest.json      version, counts, node-type histogram, content digest
    nodes.jsonl        one node record per line, sorted by uuid (no pks)
    links.jsonl        {in, out, type, label} with uuid endpoints, sorted
    logs.jsonl         {node, levelname, message, time}, sorted
    payloads/<uuid>.npy  raw .npy bytes of ArrayData nodes (kept out of
                         the jsonl so arrays are stored once, uncompressed
                         by base64, and inspectable with numpy directly)

Everything inside the zip is pk-free and deterministically ordered, so
export → import → export reproduces a byte-identical content digest (the
round-trip property the tests assert).
"""

from __future__ import annotations

import base64
import json
import zipfile
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

from repro.provenance.store import LinkType, ProvenanceStore

ARCHIVE_VERSION = 1

#: links that flow "downstream" from a process: results and sub-calls
_OUTPUT_LINKS = (LinkType.CREATE.value, LinkType.RETURN.value)
_CALL_LINKS = (LinkType.CALL_CALC.value, LinkType.CALL_WORK.value)
_INPUT_LINKS = (LinkType.INPUT_CALC.value, LinkType.INPUT_WORK.value)

#: fixed zip member timestamp — archives with equal content are equal bytes
_ZIP_DATE = (1980, 1, 1, 0, 0, 0)


class ArchiveError(RuntimeError):
    """Malformed or incompatible archive."""


# ---------------------------------------------------------------------------
# graph traversal
# ---------------------------------------------------------------------------

#: below this many total links the traversal preloads the whole link table
#: (two projected scans) and walks in memory — killing the per-level query
#: cost entirely; deep chains would otherwise still pay one query per level
_CLOSURE_PRELOAD_MAX_LINKS = 500_000


def compute_closure(store: ProvenanceStore, pks: Iterable[int], *,
                    ancestors: bool = True,
                    descendants: bool = True) -> set[int]:
    """The closed node set reachable from a selection.

    Traversal rules, applied to a worklist until fixpoint:

    * **always** — a process node pulls in its direct inputs (incoming
      ``INPUT_*`` links), so every exported process is complete and its
      ``node_hash`` is justified by data actually present in the archive;
    * **ancestors** — a data node pulls in its creator (incoming
      ``CREATE``/``RETURN``), a process pulls in its caller workflow
      (incoming ``CALL_*``): the full provenance history of the selection;
    * **descendants** — a process pulls in the data it created/returned
      (outgoing ``CREATE``/``RETURN``) and the subprocesses it called
      (outgoing ``CALL_*``). Outgoing ``INPUT_*`` links from data nodes
      are deliberately *not* followed: that would drag in every unrelated
      calculation that ever consumed a shared input.

    The walk is batched: small/medium graphs preload links + process-pk
    membership in two projected scans (no payload text is ever fetched),
    larger ones expand one BFS *level* per ``links_for``/``get_nodes``
    round trip instead of three queries per node.
    """
    seeds = {int(pk) for pk in pks}
    if not seeds:
        return set()
    found = store.get_nodes(seeds, columns=("pk",))
    missing = seeds - found.keys()
    if missing:
        raise KeyError(f"no node with pk={min(missing)}")

    if store.count_links() <= _CLOSURE_PRELOAD_MAX_LINKS:
        return _closure_preloaded(store, seeds, ancestors, descendants)
    return _closure_levelwise(store, seeds, ancestors, descendants)


def _expand(pk: int, is_process: bool,
            incoming: list[tuple[int, str]], outgoing: list[tuple[int, str]],
            ancestors: bool, descendants: bool) -> Iterable[int]:
    """Apply the traversal rules to one node's edge lists."""
    for src, lt in incoming:
        if is_process and lt in _INPUT_LINKS:
            yield src                                   # always: closure
        elif ancestors and not is_process and lt in _OUTPUT_LINKS:
            yield src                                   # creator
        elif ancestors and is_process and lt in _CALL_LINKS:
            yield src                                   # caller
    if descendants and is_process:
        for dst, lt in outgoing:
            if lt in _OUTPUT_LINKS or lt in _CALL_LINKS:
                yield dst


def _closure_preloaded(store: ProvenanceStore, seeds: set[int],
                       ancestors: bool, descendants: bool) -> set[int]:
    # raw-tuple cursor: this loop touches every link row, so Row-object
    # construction would dominate the traversal
    cur = store._conn().cursor()
    cur.row_factory = None
    cur.execute("SELECT pk FROM nodes WHERE node_type LIKE 'process%'")
    process_pks = {pk for (pk,) in cur.fetchall()}
    # bake the traversal rules into the adjacency at load time: one pass
    # categorizes every link, leaving a pure integer-graph BFS
    follow: dict[int, list[int]] = {}
    cur.execute("SELECT in_id, out_id, link_type FROM links")
    for in_id, out_id, lt in cur.fetchall():
        if lt in _INPUT_LINKS:
            if out_id in process_pks:
                follow.setdefault(out_id, []).append(in_id)   # always
        elif lt in _OUTPUT_LINKS:
            if ancestors and out_id not in process_pks:
                follow.setdefault(out_id, []).append(in_id)   # creator
            if descendants and in_id in process_pks:
                follow.setdefault(in_id, []).append(out_id)   # created
        elif lt in _CALL_LINKS:
            if ancestors and out_id in process_pks:
                follow.setdefault(out_id, []).append(in_id)   # caller
            if descendants and in_id in process_pks:
                follow.setdefault(in_id, []).append(out_id)   # callee
    seen: set[int] = set()
    frontier = list(seeds)
    while frontier:
        pk = frontier.pop()
        if pk in seen:
            continue
        seen.add(pk)
        nxt = follow.get(pk)
        if nxt:
            frontier.extend(nxt)
    return seen


def _closure_levelwise(store: ProvenanceStore, seeds: set[int],
                       ancestors: bool, descendants: bool) -> set[int]:
    seen: set[int] = set()
    is_process: dict[int, bool] = {}
    frontier = set(seeds)
    while frontier:
        unknown = [pk for pk in frontier if pk not in is_process]
        for pk, row in store.get_nodes(unknown,
                                       columns=("pk", "node_type")).items():
            is_process[pk] = row["node_type"].startswith("process")
        inc: dict[int, list[tuple[int, str]]] = {}
        out: dict[int, list[tuple[int, str]]] = {}
        for in_id, out_id, lt, _label in store.links_for(frontier):
            inc.setdefault(out_id, []).append((in_id, lt))
            out.setdefault(in_id, []).append((out_id, lt))
        seen |= frontier
        nxt: set[int] = set()
        for pk in frontier:
            nxt.update(_expand(pk, is_process[pk], inc.get(pk, ()),
                               out.get(pk, ()), ancestors, descendants))
        frontier = nxt - seen
    return seen


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

_NODE_FIELDS = ("uuid", "node_type", "process_type", "label", "description",
                "process_state", "exit_status", "exit_message", "node_hash",
                "ctime", "mtime")


def _node_record(store: ProvenanceStore, node: dict
                 ) -> tuple[dict, bytes | None]:
    """The archive representation of one node row: a pk-free JSON record,
    plus raw ``.npy`` bytes when the payload is an array (stored as a
    separate zip member referenced by uuid). Repository-backed payloads
    are resolved here, so the archive format is identical whether the
    source profile kept the content inline or in its blob store."""
    record = {f: node.get(f) for f in _NODE_FIELDS}
    record["attributes"] = json.loads(node.get("attributes") or "{}")
    # runtime attributes make no sense across profiles, and pks are
    # profile-local — `cached_from` (a uuid) is the durable reference,
    # `cached_from_pk` is reconstructed at import time
    record["attributes"].pop("kill_requested", None)
    record["attributes"].pop("paused", None)
    record["attributes"].pop("cached_from_pk", None)
    payload = node.get("payload")
    npy: bytes | None = None
    if payload is not None:
        doc = json.loads(payload)
        if doc.get("type") == "array" and "blob" in doc:
            # blob-backed array: raw bytes straight from the repository
            npy = store.repository.get(doc["blob"])
            doc = {"type": "array", "npy_ref": f"payloads/{node['uuid']}.npy"}
        elif doc.get("type") == "array" and "npy_b64" in doc:
            npy = base64.b64decode(doc["npy_b64"])
            doc = {"type": "array", "npy_ref": f"payloads/{node['uuid']}.npy"}
        else:
            # folders (and anything else) travel inline in nodes.jsonl
            doc = store.materialize_payload(doc)
        record["payload"] = doc
    else:
        record["payload"] = None
    return record, npy


def _canonical(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _content_digest(nodes: list[dict], links: list[dict],
                    logs: list[dict]) -> str:
    import hashlib

    h = hashlib.sha256()
    for section in (nodes, links, logs):
        for rec in section:
            h.update(_canonical(rec).encode())
            h.update(b"\n")
    return h.hexdigest()


def export_archive(store: ProvenanceStore, path: str,
                   pks: Iterable[int] | None = None, *,
                   ancestors: bool = True, descendants: bool = True,
                   source: str = "") -> dict:
    """Write the closure of ``pks`` (default: every node) to a zip archive
    at ``path``; returns the manifest."""
    if pks is None:
        rows = store._conn().execute("SELECT pk FROM nodes").fetchall()
        selection = {r["pk"] for r in rows}
    else:
        selection = compute_closure(store, pks, ancestors=ancestors,
                                    descendants=descendants)

    node_records: list[dict] = []
    payloads: dict[str, bytes] = {}
    uuid_of: dict[int, str] = {}
    # batched, one pass; checkpoints never enter an archive, so don't
    # drag live processes' checkpoint text through the row cache
    from repro.provenance.store import SUMMARY_COLUMNS
    rows_by_pk = store.get_nodes(selection,
                                 columns=(*SUMMARY_COLUMNS, "payload"))
    for pk in sorted(selection):
        node = rows_by_pk.get(pk)
        if node is None:
            raise KeyError(f"no node with pk={pk}")
        record, npy = _node_record(store, node)
        uuid_of[pk] = node["uuid"]
        node_records.append(record)
        if npy is not None:
            payloads[f"payloads/{node['uuid']}.npy"] = npy
    node_records.sort(key=lambda r: r["uuid"])

    # endpoint filtering happens in python: an IN (…) pair over the whole
    # selection would blow sqlite's bound-variable limit on large profiles
    rows = store._conn().execute(
        "SELECT in_id, out_id, link_type, label FROM links").fetchall()
    link_records = [{"in": uuid_of[r["in_id"]],
                     "out": uuid_of[r["out_id"]],
                     "type": r["link_type"], "label": r["label"]}
                    for r in rows
                    if r["in_id"] in selection and r["out_id"] in selection]
    link_records.sort(key=lambda r: (r["in"], r["out"], r["type"],
                                     r["label"]))

    log_records: list[dict] = []
    for pk, entries in store.logs_for(sorted(selection)).items():
        for entry in entries:
            log_records.append({"node": uuid_of[pk],
                                "levelname": entry["levelname"],
                                "message": entry["message"],
                                "time": entry["time"]})
    log_records.sort(key=lambda r: (r["node"], r["time"], r["message"]))

    types: dict[str, int] = {}
    for rec in node_records:
        types[rec["node_type"]] = types.get(rec["node_type"], 0) + 1
    manifest = {
        "archive_version": ARCHIVE_VERSION,
        "source": source,
        "nodes": len(node_records),
        "links": len(link_records),
        "logs": len(log_records),
        "payload_files": len(payloads),
        "node_types": dict(sorted(types.items())),
        "content_digest": _content_digest(node_records, link_records,
                                          log_records),
    }

    def _jsonl(records: list[dict]) -> str:
        return "".join(_canonical(r) + "\n" for r in records)

    with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as zf:
        def write(name: str, data: bytes | str) -> None:
            info = zipfile.ZipInfo(name, date_time=_ZIP_DATE)
            info.compress_type = zipfile.ZIP_DEFLATED
            zf.writestr(info, data)

        write("manifest.json", json.dumps(manifest, indent=1,
                                          sort_keys=True))
        write("nodes.jsonl", _jsonl(node_records))
        write("links.jsonl", _jsonl(link_records))
        write("logs.jsonl", _jsonl(log_records))
        for name in sorted(payloads):
            write(name, payloads[name])
    return manifest


# ---------------------------------------------------------------------------
# inspect
# ---------------------------------------------------------------------------

def _open_zip(path: str) -> zipfile.ZipFile:
    try:
        return zipfile.ZipFile(path)
    except (zipfile.BadZipFile, OSError) as exc:
        raise ArchiveError(f"{path}: cannot open archive: {exc}") from exc


def read_manifest(path: str) -> dict:
    with _open_zip(path) as zf:
        try:
            raw = zf.read("manifest.json")
        except KeyError as exc:
            raise ArchiveError(f"{path}: not a provenance archive "
                               "(no manifest.json)") from exc
    manifest = json.loads(raw)
    version = manifest.get("archive_version")
    if version != ARCHIVE_VERSION:
        raise ArchiveError(
            f"{path}: archive version {version!r} is not supported "
            f"(this build reads version {ARCHIVE_VERSION})")
    return manifest


def _read_jsonl(zf: zipfile.ZipFile, name: str) -> list[dict]:
    try:
        raw = zf.read(name)
    except KeyError:
        return []
    return [json.loads(line) for line in raw.splitlines() if line.strip()]


# ---------------------------------------------------------------------------
# import
# ---------------------------------------------------------------------------

@dataclass
class ImportResult:
    nodes_imported: int = 0
    #: archive nodes already present in the target store (same uuid)
    nodes_existing: int = 0
    #: archive process nodes skipped because an equivalent finished-ok
    #: node (same process_type + node_hash) already exists in the target
    nodes_deduped: int = 0
    #: archive nodes whose every link touches a deduped node — their
    #: content already exists attached to the target's equivalent, so
    #: importing them would create provenance-less orphans
    nodes_skipped_orphaned: int = 0
    links_imported: int = 0
    logs_imported: int = 0
    #: archive uuid -> target-store pk (existing, deduped-to or new)
    pk_map: dict[str, int] = field(default_factory=dict)

    @property
    def nodes_seen(self) -> int:
        return (self.nodes_imported + self.nodes_existing +
                self.nodes_deduped + self.nodes_skipped_orphaned)


def _dedup_target(store: ProvenanceStore, record: dict) -> dict | None:
    """An existing finished-ok node in the target store that is
    content-equivalent to this archive process record, or None."""
    if not record["node_type"].startswith("process"):
        return None
    if not record.get("node_hash"):
        return None
    if record.get("process_state") != "finished" or \
            record.get("exit_status") != 0:
        return None
    row = store._conn().execute(
        "SELECT * FROM nodes WHERE process_type=? AND node_hash=?"
        " AND process_state='finished' AND exit_status=0"
        " ORDER BY pk LIMIT 1",
        (record.get("process_type"), record["node_hash"])).fetchone()
    return dict(row) if row else None


def import_archive(store: ProvenanceStore, path: str, *,
                   dedup: bool = True,
                   progress: Callable[[str], None] | None = None
                   ) -> ImportResult:
    """Merge an archive into ``store``.

    * nodes keep their uuid; a uuid already present in the target maps to
      the existing node and is not re-inserted (re-imports are no-ops);
    * with ``dedup`` (default), a finished-ok process node whose
      ``(process_type, node_hash)`` already exists finished-ok in the
      target is *not* duplicated — the archive uuid maps to the existing
      equivalent node, the archive links/logs touching the skipped node
      are dropped (the existing node already carries its own complete
      provenance), and archive nodes *all of whose* links touch deduped
      nodes (a deduped calc's private inputs/outputs) are skipped too,
      so dedup never strands orphan data nodes;
    * links and logs are imported with endpoints remapped through the
      uuid -> pk map; exact-duplicate links are skipped, so importing
      overlapping archives cannot double-link the graph;
    * ``cached_from_pk`` attributes are rewritten to target pks when the
      referenced uuid is resolvable (the uuid in ``cached_from`` is the
      durable cross-profile reference).

    The whole merge is one store transaction: a malformed archive (e.g.
    missing payload member) rolls back cleanly instead of leaving a
    half-imported profile.
    """
    manifest = read_manifest(path)
    result = ImportResult()
    say = progress or (lambda _msg: None)

    with _open_zip(path) as zf:
        nodes = _read_jsonl(zf, "nodes.jsonl")
        links = _read_jsonl(zf, "links.jsonl")
        logs = _read_jsonl(zf, "logs.jsonl")

        # pass 1 (read-only): classify every archive node
        new_records: list[dict] = []
        deduped_uuids: set[str] = set()
        for record in nodes:
            uuid = record["uuid"]
            existing = store.get_node_by_uuid(uuid)
            if existing is not None:
                result.pk_map[uuid] = existing["pk"]
                result.nodes_existing += 1
                continue
            if dedup:
                equivalent = _dedup_target(store, record)
                if equivalent is not None:
                    result.pk_map[uuid] = equivalent["pk"]
                    result.nodes_deduped += 1
                    deduped_uuids.add(uuid)
                    continue
            new_records.append(record)

        # a new node whose every archive link touches a deduped node would
        # import with no edges at all (its links are dropped below) — its
        # content already lives attached to the target's equivalent node
        partners: dict[str, list[str]] = {}
        for link in links:
            partners.setdefault(link["in"], []).append(link["out"])
            partners.setdefault(link["out"], []).append(link["in"])
        orphaned = {r["uuid"] for r in new_records
                    if partners.get(r["uuid"]) and
                    all(p in deduped_uuids for p in partners[r["uuid"]])}
        result.nodes_skipped_orphaned = len(orphaned)

        # pass 2: one atomic merge, bulk inserts (executemany) throughout
        new_uuids: set[str] = set()
        with store.transaction():
            to_insert: list[dict] = []
            for record in new_records:
                uuid = record["uuid"]
                if uuid in orphaned:
                    continue
                payload = record.get("payload")
                if isinstance(payload, dict) and payload.get("npy_ref"):
                    try:
                        npy = zf.read(payload["npy_ref"])
                    except KeyError as exc:
                        raise ArchiveError(
                            f"{path}: missing payload member "
                            f"{payload['npy_ref']!r}") from exc
                    payload = {"type": "array",
                               "npy_b64": base64.b64encode(npy).decode()}
                row = dict(record)
                # a payload document goes in as-is: insert_node_rows
                # serializes canonically and routes bulk content above the
                # inline threshold to the blob repository (dedup by digest)
                row["payload"] = payload
                to_insert.append(row)
                new_uuids.add(uuid)
            for pk, row in zip(store.insert_node_rows(to_insert), to_insert):
                result.pk_map[row["uuid"]] = pk
            result.nodes_imported = len(to_insert)
            if to_insert:
                say(f"  {result.nodes_imported} nodes inserted...")

            link_rows: list[tuple[int, int, LinkType, str]] = []
            for link in links:
                if link["in"] in deduped_uuids or \
                        link["out"] in deduped_uuids:
                    continue
                in_pk = result.pk_map.get(link["in"])
                out_pk = result.pk_map.get(link["out"])
                if in_pk is None or out_pk is None:
                    continue  # endpoint outside the archive and the target
                lt = LinkType(link["type"])
                # fast path: links between two *new* nodes cannot pre-exist
                if not (link["in"] in new_uuids and
                        link["out"] in new_uuids) \
                        and store.has_link(in_pk, out_pk, lt, link["label"]):
                    continue
                link_rows.append((in_pk, out_pk, lt, link["label"]))
            store.add_links(link_rows)
            result.links_imported = len(link_rows)

            store.add_logs([(result.pk_map[e["node"]], e["levelname"],
                             e["message"], e["time"])
                            for e in logs if e["node"] in new_uuids])
            result.logs_imported = sum(
                1 for e in logs if e["node"] in new_uuids)

            # reconstruct cached_from_pk from the durable uuid reference;
            # raw SQL (not update_process) so the imported node's mtime
            # stays what the archive says it is
            for uuid in new_uuids:
                pk = result.pk_map[uuid]
                node = store.get_node(pk) or {}
                attrs = json.loads(node.get("attributes") or "{}")
                src_uuid = attrs.get("cached_from")
                if not src_uuid:
                    continue
                src = store.get_node_by_uuid(src_uuid)
                if src is None:
                    continue  # source outside archive and target store
                attrs["cached_from_pk"] = src["pk"]
                store._conn().execute(
                    "UPDATE nodes SET attributes=? WHERE pk=?",
                    (json.dumps(attrs), pk))

    say(f"imported {result.nodes_imported} node(s), "
        f"{result.links_imported} link(s), {result.logs_imported} log(s); "
        f"{result.nodes_existing} already present, "
        f"{result.nodes_deduped} deduplicated by content hash "
        f"(manifest digest {manifest['content_digest'][:12]}...)")
    return result
