"""Content-addressed blob repository (AiiDA 1.0 §file repository).

The provenance split the paper's criterion (v) relies on: the relational
database holds the graph (nodes, links, states — small rows, indexed,
queryable) while bulk content (array payloads, retrieved files) lives in a
flat content-addressed object store next to the database file. Rows stay
small, so graph queries never drag megabytes of base64 text through the
sqlite row cache, and identical content is stored exactly once — a blob is
keyed by the sha256 of its bytes, which makes deduplication (cache clones,
archive re-imports) automatic.

Layout on disk, for a profile at ``profile.db``::

    profile.db.repo/
        ab/ab12cd…ef      # blob whose sha256 starts with ab12…

Writes are atomic (temp file + rename into place) so concurrent daemon
workers can put the same blob without coordination: the digest *is* the
name, so last-writer-wins is byte-identical to first-writer-wins.

In-memory profiles (``:memory:``) get a dict-backed repository with the
same interface.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import threading
from typing import Iterator

from repro.observability import metrics as _metrics
from repro.observability import trace


class BlobNotFound(KeyError):
    """No blob with the requested digest in this repository."""


class BlobRepository:
    """sha256-keyed blob store; ``root=None`` keeps blobs in memory."""

    def __init__(self, root: str | None):
        self.root = root
        self._mem: dict[str, bytes] | None = None if root else {}
        self._lock = threading.Lock()
        if root:
            os.makedirs(root, exist_ok=True)

    # -- key layout ---------------------------------------------------------
    def _path(self, digest: str) -> str:
        assert self.root is not None
        return os.path.join(self.root, digest[:2], digest)

    @staticmethod
    def digest_of(data: bytes) -> str:
        return hashlib.sha256(data).hexdigest()

    # -- primitives ---------------------------------------------------------
    def put(self, data: bytes) -> str:
        """Store ``data``; returns its sha256 digest. Idempotent — putting
        bytes that are already present is a no-op (content addressing)."""
        digest = self.digest_of(data)
        _metrics.get_registry().counter("repository.puts").inc()
        if self._mem is not None:
            with self._lock:
                self._mem.setdefault(digest, bytes(data))
            return digest
        path = self._path(digest)
        if os.path.exists(path):
            return digest
        with trace.span("repo.put", size=len(data)):
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       prefix=".tmp-")
            try:
                with os.fdopen(fd, "wb") as fh:
                    fh.write(data)
                os.replace(tmp, path)  # atomic even with concurrent writers
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        return digest

    def get(self, digest: str) -> bytes:
        _metrics.get_registry().counter("repository.gets").inc()
        if self._mem is not None:
            try:
                return self._mem[digest]
            except KeyError:
                raise BlobNotFound(digest) from None
        try:
            with trace.span("repo.get"), open(self._path(digest),
                                              "rb") as fh:
                return fh.read()
        except FileNotFoundError:
            raise BlobNotFound(digest) from None

    def delete(self, digest: str) -> bool:
        """Remove one blob (fsck blob GC). Returns True when it existed.
        Safe against concurrent putters: content addressing means a racing
        put of the same digest rewrites identical bytes."""
        if self._mem is not None:
            with self._lock:
                return self._mem.pop(digest, None) is not None
        try:
            os.unlink(self._path(digest))
            return True
        except FileNotFoundError:
            return False

    def has(self, digest: str) -> bool:
        if self._mem is not None:
            return digest in self._mem
        return os.path.exists(self._path(digest))

    # -- inventory ----------------------------------------------------------
    def digests(self) -> Iterator[str]:
        if self._mem is not None:
            yield from sorted(self._mem)
            return
        if not os.path.isdir(self.root):
            return
        for fan in sorted(os.listdir(self.root)):
            sub = os.path.join(self.root, fan)
            if not os.path.isdir(sub):
                continue
            for name in sorted(os.listdir(sub)):
                if not name.startswith(".tmp-"):
                    yield name

    def stats(self) -> dict:
        """Blob count and total bytes (repository health / CLI stats)."""
        count = 0
        total = 0
        if self._mem is not None:
            return {"blobs": len(self._mem),
                    "bytes": sum(len(v) for v in self._mem.values())}
        for digest in self.digests():
            count += 1
            try:
                total += os.path.getsize(self._path(digest))
            except OSError:
                pass
        return {"blobs": count, "bytes": total}
