"""The provenance graph store (paper §I, §III.B.1).

AiiDA uses PostgreSQL; the storage backend here is sqlite (stdlib) behind
the same narrow API, with WAL journaling so that multiple daemon workers
(OS processes) can share one database file. Swapping in Postgres means
reimplementing the ~10 SQL statements in this file.

Graph model:
  nodes  — data values and process executions (CalcFunctionNode,
           WorkFunctionNode, WorkChainNode, CalcJobNode, DataNode …)
  links  — typed, labelled edges: INPUT_CALC/INPUT_WORK (data -> process),
           CREATE (calc -> data), RETURN (work -> data),
           CALL_CALC/CALL_WORK (workflow -> subprocess)
  logs   — the WorkChain.report() records (REPORT log level), attached to
           their emitting process node
"""

from __future__ import annotations

import contextlib
import enum
import json
import os
import sqlite3
import threading
import time
import uuid as uuid_mod
from typing import TYPE_CHECKING, Any, Iterable

if TYPE_CHECKING:  # imported lazily at runtime (core <-> provenance cycle)
    from repro.core.datatypes import DataValue


class NodeType(str, enum.Enum):
    DATA = "data"
    CALC_FUNCTION = "process.calcfunction"
    WORK_FUNCTION = "process.workfunction"
    WORK_CHAIN = "process.workchain"
    CALC_JOB = "process.calcjob"
    PROCESS = "process.process"

    @property
    def is_process(self) -> bool:
        return self.value.startswith("process")


class LinkType(str, enum.Enum):
    INPUT_CALC = "input_calc"
    INPUT_WORK = "input_work"
    CREATE = "create"
    RETURN = "return"
    CALL_CALC = "call_calc"
    CALL_WORK = "call_work"


_SCHEMA = """
CREATE TABLE IF NOT EXISTS nodes (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    uuid TEXT UNIQUE NOT NULL,
    node_type TEXT NOT NULL,
    process_type TEXT,
    label TEXT DEFAULT '',
    description TEXT DEFAULT '',
    attributes TEXT DEFAULT '{}',
    payload TEXT,
    process_state TEXT,
    exit_status INTEGER,
    exit_message TEXT,
    checkpoint TEXT,
    node_hash TEXT,
    ctime REAL NOT NULL,
    mtime REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS links (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    in_id INTEGER NOT NULL REFERENCES nodes(pk),
    out_id INTEGER NOT NULL REFERENCES nodes(pk),
    link_type TEXT NOT NULL,
    label TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS logs (
    pk INTEGER PRIMARY KEY AUTOINCREMENT,
    node_id INTEGER NOT NULL REFERENCES nodes(pk),
    levelname TEXT NOT NULL,
    message TEXT NOT NULL,
    time REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS meta (
    key TEXT PRIMARY KEY,
    value TEXT
);
CREATE INDEX IF NOT EXISTS idx_links_in ON links(in_id);
CREATE INDEX IF NOT EXISTS idx_links_out ON links(out_id);
CREATE INDEX IF NOT EXISTS idx_nodes_type ON nodes(node_type);
CREATE INDEX IF NOT EXISTS idx_nodes_state ON nodes(process_state);
"""


class ProvenanceStore:
    def __init__(self, path: str = ":memory:"):
        self.path = path
        self._local = threading.local()
        self._lock = threading.RLock()
        if path != ":memory:":
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._conn().executescript(_SCHEMA)
        self._migrate(self._conn())
        self._conn().commit()

    @staticmethod
    def _migrate(conn: sqlite3.Connection) -> None:
        """Bring pre-caching databases up to the current schema."""
        cols = {r[1] for r in conn.execute("PRAGMA table_info(nodes)")}
        if "node_hash" not in cols:
            conn.execute("ALTER TABLE nodes ADD COLUMN node_hash TEXT")
        # created here (not in _SCHEMA) so it runs after the column exists
        conn.execute("CREATE INDEX IF NOT EXISTS idx_nodes_hash"
                     " ON nodes(process_type, node_hash)")

    # -- connection handling (per-thread) -------------------------------------
    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = sqlite3.connect(self.path, timeout=30.0)
            conn.row_factory = sqlite3.Row
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA busy_timeout=30000")
            conn.execute("PRAGMA synchronous=NORMAL")
            self._local.conn = conn
        return conn

    def close(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            conn.close()
            self._local.conn = None

    # -- batched writes ---------------------------------------------------------
    @contextlib.contextmanager
    def transaction(self):
        """Group many mutating calls into one atomic commit (archive
        import): inside the block the per-call commits become no-ops; the
        lock is held throughout, and an exception rolls everything back."""
        with self._lock:
            if getattr(self._local, "in_txn", False):
                yield  # nested: the outermost frame owns the commit
                return
            self._local.in_txn = True
            try:
                yield
            except BaseException:
                self._conn().rollback()
                raise
            else:
                self._conn().commit()
            finally:
                self._local.in_txn = False

    def _commit(self) -> None:
        if not getattr(self._local, "in_txn", False):
            self._conn().commit()

    # -- node creation -----------------------------------------------------------
    def store_data(self, value: "DataValue", label: str = "") -> "DataValue":
        """Persist a DataValue; idempotent if already stored."""
        if value.is_stored:
            return value
        now = time.time()
        u = str(uuid_mod.uuid4())
        with self._lock:
            cur = self._conn().execute(
                "INSERT INTO nodes (uuid, node_type, label, payload, ctime,"
                " mtime) VALUES (?,?,?,?,?,?)",
                (u, NodeType.DATA.value, label,
                 json.dumps(value.to_payload()), now, now))
            self._commit()
        value.pk = cur.lastrowid
        value.uuid = u
        return value

    def create_process_node(self, node_type: NodeType, process_type: str,
                            label: str = "", description: str = "",
                            attributes: dict | None = None,
                            node_hash: str | None = None) -> int:
        now = time.time()
        u = str(uuid_mod.uuid4())
        with self._lock:
            cur = self._conn().execute(
                "INSERT INTO nodes (uuid, node_type, process_type, label,"
                " description, attributes, process_state, node_hash, ctime,"
                " mtime) VALUES (?,?,?,?,?,?,?,?,?,?)",
                (u, node_type.value, process_type, label, description,
                 json.dumps(attributes or {}), "created", node_hash, now,
                 now))
            self._commit()
        return cur.lastrowid

    # -- node updates ----------------------------------------------------------
    def update_process(self, pk: int, *, state: str | None = None,
                       exit_status: int | None = None,
                       exit_message: str | None = None,
                       attributes: dict | None = None) -> None:
        sets, vals = ["mtime=?"], [time.time()]
        if state is not None:
            sets.append("process_state=?")
            vals.append(state)
        if exit_status is not None:
            sets.append("exit_status=?")
            vals.append(exit_status)
        if exit_message is not None:
            sets.append("exit_message=?")
            vals.append(exit_message)
        vals.append(pk)
        with self._lock:
            if attributes is not None:
                # merge, don't replace — e.g. `cached_from` (and the durable
                # `kill_requested` control marker) must survive the
                # state-transition attribute writes. Merge in SQL: a python
                # read-modify-write would race against writers in OTHER OS
                # processes (daemon workers vs a control CLI) and lose keys.
                # NB json_patch treats a null value as key deletion; no
                # caller stores None attribute values.
                try:
                    self._conn().execute(
                        "UPDATE nodes SET attributes="
                        "json_patch(COALESCE(attributes,'{}'),?) WHERE pk=?",
                        (json.dumps(attributes), pk))
                except sqlite3.OperationalError:
                    # sqlite built without JSON1: best-effort python merge
                    row = self._conn().execute(
                        "SELECT attributes FROM nodes WHERE pk=?",
                        (pk,)).fetchone()
                    merged = (json.loads(row["attributes"] or "{}")
                              if row else {})
                    merged.update(attributes)
                    self._conn().execute(
                        "UPDATE nodes SET attributes=? WHERE pk=?",
                        (json.dumps(merged), pk))
            self._conn().execute(
                f"UPDATE nodes SET {', '.join(sets)} WHERE pk=?", vals)
            self._commit()

    # -- store-level counters/metadata (telemetry, e.g. hash collisions) -------
    def incr_meta(self, key: str, by: int = 1) -> int:
        """Atomically increment a store-level integer counter; returns the
        new value. Safe across OS processes (single UPSERT statement)."""
        with self._lock:
            self._conn().execute(
                "INSERT INTO meta (key, value) VALUES (?, ?)"
                " ON CONFLICT(key) DO UPDATE SET"
                " value = CAST(CAST(value AS INTEGER) + ? AS TEXT)",
                (key, str(by), by))
            self._commit()
            row = self._conn().execute(
                "SELECT value FROM meta WHERE key=?", (key,)).fetchone()
        return int(row["value"])

    def get_meta(self, key: str, default: Any = None) -> Any:
        row = self._conn().execute(
            "SELECT value FROM meta WHERE key=?", (key,)).fetchone()
        return row["value"] if row is not None else default

    def all_meta(self, prefix: str = "") -> dict[str, str]:
        rows = self._conn().execute(
            "SELECT key, value FROM meta WHERE key LIKE ?"
            " ORDER BY key", (prefix + "%",)).fetchall()
        return {r["key"]: r["value"] for r in rows}

    def set_node_hash(self, pk: int, node_hash: str | None) -> None:
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET node_hash=?, mtime=? WHERE pk=?",
                (node_hash, time.time(), pk))
            self._commit()

    def save_checkpoint(self, pk: int, checkpoint: dict) -> None:
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET checkpoint=?, mtime=? WHERE pk=?",
                (json.dumps(checkpoint), time.time(), pk))
            self._commit()

    def load_checkpoint(self, pk: int) -> dict | None:
        row = self._conn().execute(
            "SELECT checkpoint FROM nodes WHERE pk=?", (pk,)).fetchone()
        if row is None or row["checkpoint"] is None:
            return None
        return json.loads(row["checkpoint"])

    def delete_checkpoint(self, pk: int) -> None:
        with self._lock:
            self._conn().execute(
                "UPDATE nodes SET checkpoint=NULL WHERE pk=?", (pk,))
            self._commit()

    # -- bulk insertion (archive import) ---------------------------------------
    def insert_node_row(self, record: dict) -> int:
        """Insert a complete node row (archive import path): the caller
        supplies the uuid and timestamps, so identity and history survive
        the trip between profiles. Returns the assigned pk."""
        with self._lock:
            cur = self._conn().execute(
                "INSERT INTO nodes (uuid, node_type, process_type, label,"
                " description, attributes, payload, process_state,"
                " exit_status, exit_message, node_hash, ctime, mtime)"
                " VALUES (?,?,?,?,?,?,?,?,?,?,?,?,?)",
                (record["uuid"], record["node_type"],
                 record.get("process_type"), record.get("label", ""),
                 record.get("description", ""),
                 json.dumps(record.get("attributes") or {}),
                 record.get("payload"), record.get("process_state"),
                 record.get("exit_status"), record.get("exit_message"),
                 record.get("node_hash"),
                 record.get("ctime", time.time()),
                 record.get("mtime", time.time())))
            self._commit()
        return cur.lastrowid

    def get_node_by_uuid(self, uuid: str) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM nodes WHERE uuid=?", (uuid,)).fetchone()
        return dict(row) if row else None

    # -- links -------------------------------------------------------------------
    def add_link(self, in_pk: int, out_pk: int, link_type: LinkType,
                 label: str) -> None:
        with self._lock:
            self._conn().execute(
                "INSERT INTO links (in_id, out_id, link_type, label)"
                " VALUES (?,?,?,?)", (in_pk, out_pk, link_type.value, label))
            self._commit()

    def has_link(self, in_pk: int, out_pk: int, link_type: LinkType,
                 label: str) -> bool:
        row = self._conn().execute(
            "SELECT 1 FROM links WHERE in_id=? AND out_id=? AND link_type=?"
            " AND label=? LIMIT 1",
            (in_pk, out_pk, link_type.value, label)).fetchone()
        return row is not None

    def delete_outgoing_links(self, in_pk: int,
                              link_types: Iterable[LinkType]) -> None:
        """Remove typed edges leaving a node (cache-clone rollback)."""
        types = [lt.value for lt in link_types]
        marks = ",".join("?" * len(types))
        with self._lock:
            self._conn().execute(
                f"DELETE FROM links WHERE in_id=? AND link_type IN ({marks})",
                [in_pk, *types])
            self._commit()

    # -- logs ----------------------------------------------------------------------
    def add_log(self, node_pk: int, levelname: str, message: str,
                ts: float | None = None) -> None:
        """Attach a log record; ``ts`` overrides the wall clock so imported
        logs keep their original emission time."""
        with self._lock:
            self._conn().execute(
                "INSERT INTO logs (node_id, levelname, message, time)"
                " VALUES (?,?,?,?)",
                (node_pk, levelname, message,
                 time.time() if ts is None else ts))
            self._commit()

    def get_logs(self, node_pk: int) -> list[dict]:
        rows = self._conn().execute(
            "SELECT levelname, message, time FROM logs WHERE node_id=?"
            " ORDER BY pk", (node_pk,)).fetchall()
        return [dict(r) for r in rows]

    # -- reads -----------------------------------------------------------------------
    def get_node(self, pk: int) -> dict | None:
        row = self._conn().execute(
            "SELECT * FROM nodes WHERE pk=?", (pk,)).fetchone()
        return dict(row) if row else None

    def load_data(self, pk: int) -> "DataValue":
        from repro.core.datatypes import DataValue

        node = self.get_node(pk)
        if node is None or node["node_type"] != NodeType.DATA.value:
            raise KeyError(f"no data node with pk={pk}")
        value = DataValue.from_payload(json.loads(node["payload"]))
        value.pk = pk
        value.uuid = node["uuid"]
        return value

    def incoming(self, pk: int, link_type: LinkType | None = None
                 ) -> list[tuple[int, str, str]]:
        q = "SELECT in_id, link_type, label FROM links WHERE out_id=?"
        args: list[Any] = [pk]
        if link_type:
            q += " AND link_type=?"
            args.append(link_type.value)
        return [(r["in_id"], r["link_type"], r["label"])
                for r in self._conn().execute(q, args)]

    def outgoing(self, pk: int, link_type: LinkType | None = None
                 ) -> list[tuple[int, str, str]]:
        q = "SELECT out_id, link_type, label FROM links WHERE in_id=?"
        args: list[Any] = [pk]
        if link_type:
            q += " AND link_type=?"
            args.append(link_type.value)
        return [(r["out_id"], r["link_type"], r["label"])
                for r in self._conn().execute(q, args)]

    def count_nodes(self, node_type: NodeType | None = None) -> int:
        if node_type is None:
            return self._conn().execute(
                "SELECT COUNT(*) c FROM nodes").fetchone()["c"]
        return self._conn().execute(
            "SELECT COUNT(*) c FROM nodes WHERE node_type=?",
            (node_type.value,)).fetchone()["c"]

    def unfinished_processes(self) -> list[dict]:
        rows = self._conn().execute(
            "SELECT * FROM nodes WHERE node_type LIKE 'process%' AND"
            " process_state NOT IN ('finished','excepted','killed')"
        ).fetchall()
        return [dict(r) for r in rows]


class QueryBuilder:
    """Minimal, composable query interface over the provenance graph —
    the criterion-(iv) 'easily queryable' surface."""

    def __init__(self, store: ProvenanceStore):
        self.store = store
        self._wheres: list[str] = []
        self._args: list[Any] = []
        self._order = "pk"
        self._limit: int | None = None

    def nodes(self, node_type: NodeType | str | None = None) -> "QueryBuilder":
        if node_type is not None:
            t = node_type.value if isinstance(node_type, NodeType) else node_type
            self._wheres.append("node_type LIKE ?")
            self._args.append(f"{t}%")
        return self

    def with_node_types(self, node_types: Iterable[NodeType | str]
                        ) -> "QueryBuilder":
        """Exact node-type membership (no prefix matching)."""
        types = [t.value if isinstance(t, NodeType) else t
                 for t in node_types]
        marks = ",".join("?" * len(types))
        self._wheres.append(f"node_type IN ({marks})")
        self._args.extend(types)
        return self

    def with_null_hash(self) -> "QueryBuilder":
        """Nodes with no input fingerprint (legacy / invalidated)."""
        self._wheres.append("node_hash IS NULL")
        return self

    def with_process_type(self, process_type: str) -> "QueryBuilder":
        self._wheres.append("process_type=?")
        self._args.append(process_type)
        return self

    def with_hash(self, node_hash: str) -> "QueryBuilder":
        self._wheres.append("node_hash=?")
        self._args.append(node_hash)
        return self

    def with_state(self, state: str) -> "QueryBuilder":
        self._wheres.append("process_state=?")
        self._args.append(state)
        return self

    def with_exit_status(self, status: int) -> "QueryBuilder":
        self._wheres.append("exit_status=?")
        self._args.append(status)
        return self

    def with_label(self, label: str) -> "QueryBuilder":
        self._wheres.append("label=?")
        self._args.append(label)
        return self

    def created_after(self, ts: float) -> "QueryBuilder":
        self._wheres.append("ctime>=?")
        self._args.append(ts)
        return self

    def order_by(self, field: str, desc: bool = False) -> "QueryBuilder":
        assert field in ("pk", "ctime", "mtime")
        self._order = field + (" DESC" if desc else "")
        return self

    def limit(self, n: int) -> "QueryBuilder":
        self._limit = n
        return self

    def all(self) -> list[dict]:
        q = "SELECT * FROM nodes"
        if self._wheres:
            q += " WHERE " + " AND ".join(self._wheres)
        q += f" ORDER BY {self._order}"
        if self._limit:
            q += f" LIMIT {self._limit}"
        return [dict(r) for r in self.store._conn().execute(q, self._args)]

    def count(self) -> int:
        q = "SELECT COUNT(*) c FROM nodes"
        if self._wheres:
            q += " WHERE " + " AND ".join(self._wheres)
        return self.store._conn().execute(q, self._args).fetchone()["c"]

    def first(self) -> dict | None:
        res = self.limit(1).all()
        return res[0] if res else None


# ---------------------------------------------------------------------------
# Global store configuration (one per python instance, like AiiDA profiles)
# ---------------------------------------------------------------------------

_STORE: ProvenanceStore | None = None


def configure_store(path: str = ":memory:") -> ProvenanceStore:
    global _STORE
    _STORE = ProvenanceStore(path)
    return _STORE


def current_store() -> ProvenanceStore:
    global _STORE
    if _STORE is None:
        _STORE = ProvenanceStore(":memory:")
    return _STORE
